"""repro.obs: per-request tracing, exporters, and the unified stats schema.

Fast unit coverage of the span model (Span/TraceContext/Tracer/Timeline),
the exporters (Chrome trace_event, Prometheus text, schema validation),
the repro.settings registry, and the StatsSnapshot legacy-key aliases —
then real-pool integration: a SIGKILLed worker mid-request must leave its
footprint (a send span to the dead worker AND a re-dispatched send span)
on the same merged timeline as the surviving responders' compute spans,
a v0 peer (no "tracing" capability) must still yield a synthesized
compute span without ever seeing a trace header, and results must be
bit-identical with tracing on vs. off.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import json
import threading
import time
import warnings

import numpy as np
import pytest

from repro import obs, settings
from repro.obs.trace import Span, Timeline, TraceContext, Tracer
from repro.stats import StatsSnapshot, merge_snapshots, namespaced

Z32 = None  # built lazily in the pool section (keeps unit tests jax-free)


@pytest.fixture(autouse=True)
def _reset_tracing():
    """Every test starts with tracing off and a clean ring buffer."""
    obs.set_enabled(None)
    obs.tracer().clear()
    yield
    obs.set_enabled(None)
    obs.tracer().clear()


# --------------------------------------------------------------------------
# span model
# --------------------------------------------------------------------------


def test_span_and_timeline_json_roundtrip():
    s = Span("t-1", "compute", "worker", 10.0, 10.5, {"wid": 3, "ok": True})
    assert s.duration_s == pytest.approx(0.5)
    assert Span.from_json(json.loads(json.dumps(s.to_json()))) == s
    tl = Timeline("t-1", [s])
    doc = json.loads(json.dumps(tl.to_json()))
    back = Timeline.from_json(doc)
    assert back.trace_id == "t-1" and back.spans == [s]
    assert tl.wall_s == pytest.approx(0.5)
    assert tl.by_component("worker") == [s]
    assert tl.by_component("pool") == []


def test_trace_ids_are_process_unique():
    ids = {obs.new_trace_id("x") for _ in range(100)}
    assert len(ids) == 100
    assert all(i.startswith("x-") for i in ids)


def test_now_is_monotone_and_epoch_aligned():
    a = obs.now()
    b = obs.now()
    assert b >= a
    assert abs(a - time.time()) < 1.0  # anchored to the epoch


def test_tracer_ring_buffer_bounded_and_filtered():
    tr = Tracer(capacity=4)
    ctx_a = TraceContext.new("a")
    ctx_b = TraceContext.new("b")
    for i in range(6):
        ctx = ctx_a if i % 2 == 0 else ctx_b
        tr.add(ctx, f"s{i}", "pool", float(i), float(i) + 0.1)
    assert len(tr) == 4  # oldest two evicted
    got = tr.spans(ctx_a.trace_id)
    assert all(s.trace_id == ctx_a.trace_id for s in got)
    merged = tr.timeline(ctx_a.trace_id, ctx_b.trace_id)
    assert len(merged.spans) == 4
    starts = [s.t_start for s in merged.spans]
    assert starts == sorted(starts)


def test_tracer_add_none_ctx_is_noop():
    tr = Tracer(capacity=8)
    assert tr.add(None, "x", "pool", 0.0, 1.0) is None
    assert len(tr) == 0


def test_span_contextmanager_nesting_sets_parent_tag():
    tr = Tracer(capacity=8)
    ctx = TraceContext.new("t")
    with tr.span(ctx, "outer", "pool"):
        with tr.span(ctx, "inner", "pool") as tags:
            tags["extra"] = 7
    spans = {s.name: s for s in tr.spans(ctx.trace_id)}
    assert spans["inner"].tags["parent"] == "outer"
    assert spans["inner"].tags["extra"] == 7
    assert "parent" not in spans["outer"].tags
    assert spans["outer"].t_start <= spans["inner"].t_start
    assert spans["inner"].t_end <= spans["outer"].t_end
    assert ctx.stack == []  # fully unwound


def test_enablement_gates_context_creation():
    obs.set_enabled(False)
    assert obs.maybe_context("t") is None
    obs.set_enabled(True)
    ctx = obs.maybe_context("t", request_id=5)
    assert ctx is not None and ctx.request_id == 5
    obs.set_enabled(None)  # fall back to the (unset) env setting
    assert obs.maybe_context("t") is None


def test_wire_roundtrip_restamps_trace_id_and_tags():
    spans = [Span("ignored", "compute", "worker", 1.0, 2.0, {"pid": 42})]
    wire = obs.spans_to_wire(spans)
    assert "trace_id" not in wire[0]
    back = obs.spans_from_wire(wire, "t-9", wid=3, share=1)
    assert back[0].trace_id == "t-9"
    assert back[0].tags == {"pid": 42, "wid": 3, "share": 1}
    assert back[0].t_start == 1.0 and back[0].t_end == 2.0


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------


def _sample_timeline():
    return Timeline("t-1", [
        Span("t-1", "encode", "pool", 0.0, 0.1, {"share": 0}),
        Span("t-1", "send", "pool", 0.1, 0.2, {"wid": 0, "share": 0}),
        Span("t-1", "compute", "worker", 0.2, 0.6, {"wid": 0}),
        Span("t-1", "compute", "worker", 0.25, 0.7, {"wid": 1}),
        Span("t-1", "decode", "pool", 0.7, 0.8, {}),
    ])


def test_chrome_trace_export_structure():
    doc = json.loads(obs.to_chrome_trace(_sample_timeline()))
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == 5
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] > 0  # relative microseconds
    # worker spans land in per-worker lanes; metadata names them
    names = [e for e in events if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in names)
    worker_tids = {e["tid"] for e in xs if e["name"] == "compute"}
    assert len(worker_tids) == 2


def test_prometheus_export_counters_hist_gauges():
    snap = namespaced("pool", {
        "requests": 3,
        "wall_ms_hist": {"<=1": 1, "<=5": 2, "inf": 3},
        "wall_ms_p50": 2.0,
        "wall_ms_p99": 5.0,
        "transport": "pack",  # non-numeric: skipped
    })
    text = obs.to_prometheus(snap)
    assert "# HELP repro_pool_requests" in text
    assert "# TYPE repro_pool_requests counter" in text
    assert "repro_pool_requests 3" in text
    assert 'le="1"' in text and 'le="+Inf"' in text
    # the histogram family keeps the snapshot's base name (no doubled
    # unit suffix) and carries the full cumulative triple
    assert "# TYPE repro_pool_wall_ms histogram" in text
    assert "repro_pool_wall_ms_bucket" in text
    assert "repro_pool_wall_ms_count 6" in text
    assert "repro_pool_wall_ms_p50 2.0" in text
    assert "transport" not in text
    # the exposition parses strictly (the CI scrape oracle)
    obs.parse_prometheus(text)


def test_validate_timeline_accepts_good_rejects_bad():
    good = _sample_timeline().to_json()
    assert obs.validate_timeline(
        good, min_workers=2, require_components=("pool", "worker")
    ) == []
    assert obs.validate_timeline({"trace_id": "t", "spans": []})
    backwards = {"trace_id": "t", "spans": [
        {"trace_id": "t", "name": "x", "component": "pool",
         "t_start": 2.0, "t_end": 1.0, "tags": {}},
    ]}
    assert any(
        "ends before" in p for p in obs.validate_timeline(backwards)
    )
    assert any(
        "worker" in p
        for p in obs.validate_timeline(good, min_workers=3)
    )
    assert any(
        "serve" in p
        for p in obs.validate_timeline(good, require_components=("serve",))
    )


# --------------------------------------------------------------------------
# repro.settings
# --------------------------------------------------------------------------


def test_settings_defaults_and_parsing():
    assert settings.get("trace", env={}) is False
    assert settings.get_bool("trace", env={"REPRO_TRACE": "yes"}) is True
    assert settings.get_bool("trace", env={"REPRO_TRACE": "0"}) is False
    assert settings.get_int("trace_buffer", env={}) == 8192
    assert settings.get_int(
        "trace_buffer", env={"REPRO_TRACE_BUFFER": "16"}
    ) == 16
    assert settings.get("calibration", env={}) is None


def test_settings_legacy_fallback_warns_once():
    settings._WARNED.discard("REPRO_POOL_WORKERS")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert settings.get_int(
            "dist_workers", env={"REPRO_POOL_WORKERS": "5"}
        ) == 5
        assert settings.get_int(
            "dist_workers", env={"REPRO_POOL_WORKERS": "5"}
        ) == 5
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1
    assert "REPRO_POOL_WORKERS" in str(deps[0].message)
    # the new variable wins when both are set
    assert settings.get_int("dist_workers", env={
        "REPRO_POOL_WORKERS": "5", "REPRO_DIST_WORKERS": "7",
    }) == 7


def test_settings_describe_lists_every_knob():
    text = settings.describe()
    for s in settings.SETTINGS.values():
        assert s.env in text
    assert "REPRO_POOL_WORKERS" in text  # legacy shims are documented too


# --------------------------------------------------------------------------
# unified stats schema
# --------------------------------------------------------------------------


def test_namespaced_prefixes_and_aliases():
    snap = namespaced("serve", {"submitted": 3, "wait_ms_p50": 1.0})
    assert snap["serve_submitted"] == 3
    settings._WARNED.discard("stats:submitted")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert snap["submitted"] == 3  # legacy key resolves
        assert snap["submitted"] == 3
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1
    assert "submitted" in snap and "serve_submitted" in snap
    assert snap.get("nope", 9) == 9
    with pytest.raises(KeyError):
        snap["serve_nope"]


def test_namespaced_is_idempotent():
    once = namespaced("pool", {"requests": 1})
    twice = namespaced("pool", once)
    assert dict(twice) == {"pool_requests": 1}


def test_merge_snapshots_preserves_aliases():
    merged = merge_snapshots(
        namespaced("serve", {"submitted": 2}),
        namespaced("pool", {"requests": 1}),
    )
    assert isinstance(merged, StatsSnapshot)
    assert merged["serve_submitted"] == 2 and merged["pool_requests"] == 1
    assert merged["requests"] == 1  # legacy alias survives the merge


# --------------------------------------------------------------------------
# calibration rows from measured spans
# --------------------------------------------------------------------------


def test_rows_from_timeline_feeds_fit_rows():
    from repro.cdmm.calibrate import fit_rows, rows_from_timeline
    from repro.core.ep_codes import EPCosts

    costs = EPCosts(N=4, R=3, m_eff=1.0, upload=100.0, download=50.0,
                    encode_ops=1000.0, decode_ops=500.0, worker_ops=2000.0)
    tl = Timeline("t-1", [
        Span("t-1", "encode", "pool", 0.0, 0.010, {}),
        Span("t-1", "encode", "pool", 0.010, 0.030, {}),
        Span("t-1", "send", "pool", 0.030, 0.040, {"wid": 0}),
        Span("t-1", "compute", "worker", 0.04, 0.24, {"wid": 0}),
        Span("t-1", "compute", "worker", 0.05, 0.29, {"wid": 1}),
        Span("t-1", "decode", "pool", 0.30, 0.35, {}),
        Span("t-1", "wait_R", "pool", 0.04, 0.30, {}),  # not a fit stage
    ])
    rows = rows_from_timeline(tl, costs, backend="pool")
    by_name = {}
    for r in rows:
        by_name.setdefault(r["name"], []).append(r)
    # serial stages pool into one row; each worker compute is its own
    assert len(by_name["trace_pool_encode"]) == 1
    assert by_name["trace_pool_encode"][0]["us"] == pytest.approx(3e4)
    assert len(by_name["trace_pool_worker"]) == 2
    assert by_name["trace_pool_decode"][0]["derived"]["decode_ops"] == 500.0
    assert "trace_pool_wait_R" not in by_name
    cal = fit_rows(rows)
    assert "pool" in cal.backends
    # the fitted compute slope reproduces the mean observed span
    coef = cal.backends["pool"].coef["compute"]
    assert coef * costs.worker_ops == pytest.approx(225_000, rel=0.15)


# --------------------------------------------------------------------------
# real worker processes (tracing through the pool and the serve engine)
# --------------------------------------------------------------------------

pool_tests = pytest.mark.slow


@pytest.fixture(scope="module")
def pool():
    from repro.dist import LocalPool

    with LocalPool(workers=4) as p:
        yield p


def _scheme_and_problem(N=4, budget=1, size=8, seed=0):
    from repro.cdmm import ProblemSpec, plan
    from repro.core import make_ring

    ring = make_ring(2, 32, ())
    spec = ProblemSpec(t=size, r=size, s=size, n=1, ring=ring, N=N,
                       straggler_budget=budget)
    scheme = plan(spec).instantiate()
    rng = np.random.default_rng(seed)
    A = ring.random(rng, (size, size))
    B = ring.random(rng, (size, size))
    return ring, scheme, A, B


@pool_tests
def test_pool_trace_covers_every_stage_and_responder(pool):
    ring, scheme, A, B = _scheme_and_problem()
    obs.set_enabled(True)
    ctx = TraceContext.new("test")
    C, stats = pool.master.execute(scheme, A, B, trace=ctx)
    np.testing.assert_array_equal(
        np.asarray(C), np.asarray(ring.matmul(A, B))
    )
    tl = obs.tracer().timeline(ctx.trace_id)
    assert obs.validate_timeline(
        tl.to_json(), min_workers=scheme.R,
        require_components=("pool", "worker"),
    ) == []
    names = {s.name for s in tl.spans}
    assert {"encode", "send", "compute", "wait_R", "decode"} <= names
    computes = [s for s in tl.spans if s.name == "compute"]
    assert len({s.tags["wid"] for s in computes}) >= scheme.R
    # none synthesized: every live worker advertised the tracing capability
    assert not any(s.tags.get("synthesized") for s in computes)
    # serial master-side stage time fits inside the request wall clock
    serial = sum(
        s.duration_s for s in tl.spans
        if s.component == "pool" and s.name in ("encode", "send", "decode")
    )
    assert serial <= tl.wall_s + 1e-9


@pool_tests
def test_pool_trace_bit_identical_on_vs_off(pool):
    ring, scheme, A, B = _scheme_and_problem(seed=3)
    obs.set_enabled(False)
    C_off, _ = pool.master.execute(scheme, A, B)
    obs.set_enabled(True)
    C_on, _ = pool.master.execute(
        scheme, A, B, trace=TraceContext.new("test")
    )
    np.testing.assert_array_equal(np.asarray(C_off), np.asarray(C_on))
    assert len(obs.tracer()) > 0  # tracing actually recorded


@pool_tests
def test_pool_trace_v0_peer_interop_synthesizes_spans(pool):
    # strip the "tracing" capability from every handle: the master must
    # never stamp a trace header (a v0 worker would reject unknown
    # semantics) and must synthesize compute spans from wall_us instead
    master = pool.master
    removed = {}
    for wid, h in master._workers.items():
        if "tracing" in h.caps:
            removed[wid] = h.caps.pop("tracing")
    try:
        ring, scheme, A, B = _scheme_and_problem(seed=5)
        obs.set_enabled(True)
        ctx = TraceContext.new("test")
        C, _ = master.execute(scheme, A, B, trace=ctx)
        np.testing.assert_array_equal(
            np.asarray(C), np.asarray(ring.matmul(A, B))
        )
        tl = obs.tracer().timeline(ctx.trace_id)
        computes = [s for s in tl.spans if s.name == "compute"]
        assert len(computes) >= scheme.R
        assert all(s.tags.get("synthesized") for s in computes)
        assert all(s.t_end >= s.t_start for s in computes)
    finally:
        for wid, v in removed.items():
            if wid in master._workers:
                master._workers[wid].caps["tracing"] = v


@pool_tests
def test_pool_trace_sigkill_leaves_dead_worker_footprint(pool):
    # a kill-resilient scheme on a dedicated pool: SIGKILL one worker
    # mid-request; the merged timeline must show the send to the dead
    # worker AND the re-dispatched replacement share AND >= R compute
    # spans from the survivors — the full story of the any-R race
    from repro.dist import LocalPool

    ring, scheme, A, B = _scheme_and_problem(N=4, budget=1, size=16)
    oracle = np.asarray(ring.matmul(A, B))
    with LocalPool(workers=scheme.N) as victim_pool:
        master = victim_pool.master
        warm, _ = master.execute(scheme, A, B)  # jit before the race
        np.testing.assert_array_equal(np.asarray(warm), oracle)
        for wid in master.live_workers():
            master.task_delay_ms[wid] = 300.0
        obs.set_enabled(True)
        ctx = TraceContext.new("test")
        result = {}

        def _request():
            result["C"], result["stats"] = master.execute(
                scheme, A, B, trace=ctx
            )

        t = threading.Thread(target=_request)
        t.start()
        time.sleep(0.075)  # tasks dispatched, workers parked
        killed = victim_pool.kill(1)
        assert killed
        t.join(timeout=120)
        assert not t.is_alive()
    np.testing.assert_array_equal(np.asarray(result["C"]), oracle)
    assert result["stats"].redispatched >= 1
    tl = obs.tracer().timeline(ctx.trace_id)
    assert obs.validate_timeline(
        tl.to_json(), min_workers=scheme.R,
        require_components=("pool", "worker"),
    ) == []
    sends = [s for s in tl.spans if s.name == "send"]
    assert any(s.tags.get("redispatch") for s in sends)
    # every share's original dispatch is on the timeline, so the dead
    # worker's send span is the evidence of the share it never finished
    assert len(sends) >= scheme.N
    computes = [s for s in tl.spans if s.name == "compute"]
    assert len({s.tags["wid"] for s in computes}) >= scheme.R


@pool_tests
def test_serve_trace_merges_request_and_carrier(pool):
    from repro.cdmm import ProblemSpec
    from repro.core import make_ring
    from repro.serve import CoalescePolicy, ServeScheduler

    ring = make_ring(2, 32, ())
    spec = ProblemSpec(t=16, r=16, s=16, n=1, ring=ring, N=4,
                       straggler_budget=1)
    rng = np.random.default_rng(0)
    pairs = [
        (ring.random(rng, (16, 16)), ring.random(rng, (16, 16)))
        for _ in range(4)
    ]
    obs.set_enabled(True)
    with ServeScheduler(
        pool.master, CoalescePolicy(target_batch_n=4, max_wait_ms=100.0),
        max_queue=8, seed=0,
    ) as sched:
        futs = [sched.submit(A, B, spec=spec) for A, B in pairs]
        for fut, (A, B) in zip(futs, pairs):
            np.testing.assert_array_equal(
                np.asarray(fut.result(120)),
                np.asarray(ring.matmul(A, B)),
            )
        for fut in futs:
            tl = sched.trace(fut)
            comps = {s.component for s in tl.spans}
            # every request's merged timeline reaches through the carrier
            # to the pool and worker spans of its batch
            assert {"serve", "pool", "worker"} <= comps
            assert any(s.name == "coalesce_wait" for s in tl.spans)
            assert any(s.name == "decode" for s in tl.spans)
        with pytest.raises(KeyError):
            sched.trace(10**9)
    obs.set_enabled(False)
    with pytest.raises(ValueError):
        sched.trace(futs[0])


@pool_tests
def test_scheduler_trace_by_future(pool):
    from repro.dist import PoolScheduler

    ring, scheme, A, B = _scheme_and_problem(seed=7)
    obs.set_enabled(True)
    sched = PoolScheduler(pool.master, max_inflight=2)
    try:
        fut = sched.submit(A, B, scheme=scheme)
        np.testing.assert_array_equal(
            np.asarray(fut.result(120)),
            np.asarray(ring.matmul(A, B)),
        )
        tl = sched.trace(fut)
        names = {s.name for s in tl.spans}
        assert "queue_wait" in names and "decode" in names
    finally:
        sched.close()
