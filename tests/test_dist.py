"""repro.dist: the multi-process worker-pool runtime, tested for real.

Covers the wire protocol (frame/codec round-trips), the membership
bookkeeping (``MembershipEvents`` -> ``WorkerTrace``), and — against a
real pool of worker OS processes shared across the module — pool-vs-local
bit-identicality, share multiplexing (scheme.N > pool size), the serving
scheduler (concurrency, plan cache, admission control), and the headline
failure-injection property: SIGKILL N - R workers MID-REQUEST and the
any-R decode still returns the oracle product bit for bit.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import socket
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.cdmm import ProblemSpec, coded_matmul, plan
from repro.core import make_ring
from repro.core.straggler import MembershipEvents
from repro.dist import (
    LocalPool,
    PoolBackend,
    PoolScheduler,
    SchedulerSaturated,
)
from repro.dist.protocol import (
    ProtocolError,
    connect,
    listen,
    parse_address,
    recv_msg,
    send_msg,
)

# multi-process pool smokes dominate tier-1 wall time; deselected by
# `tools/ci.sh --fast` (see tests/conftest.py for the marker)
pytestmark = pytest.mark.slow

Z32 = make_ring(2, 32, ())
KEY = jax.random.PRNGKey(7)
POOL_WORKERS = 4


# --------------------------------------------------------------------------
# protocol (no processes involved)
# --------------------------------------------------------------------------


def _socketpair():
    a, b = socket.socketpair()
    return a, b


def test_protocol_roundtrip_arrays():
    a, b = _socketpair()
    arrays = {
        "fa": np.arange(12, dtype=np.uint32).reshape(3, 4),
        "gb": np.zeros((2, 2, 5), dtype=np.uint32),
    }
    send_msg(a, {"type": "task", "i": 3, "nested": {"x": [1, 2]}}, arrays)
    header, got = recv_msg(b)
    assert header["type"] == "task" and header["i"] == 3
    assert header["nested"] == {"x": [1, 2]}
    assert sorted(got) == ["fa", "gb"]
    for name in arrays:
        assert got[name].dtype == arrays[name].dtype
        np.testing.assert_array_equal(got[name], arrays[name])
    a.close(), b.close()


def test_protocol_empty_arrays_and_many_messages():
    a, b = _socketpair()
    for k in range(5):
        send_msg(a, {"k": k})
    for k in range(5):
        header, got = recv_msg(b)
        assert header["k"] == k and got == {}
    a.close(), b.close()


def test_protocol_peer_hangup_raises():
    a, b = _socketpair()
    a.sendall(b"\x00\x00\x01\x00partial")  # 256-byte frame, 7 bytes sent
    a.close()
    with pytest.raises(ProtocolError):
        recv_msg(b)
    b.close()


def test_parse_address():
    assert parse_address("tcp:127.0.0.1:80") == ("tcp", ("127.0.0.1", 80))
    assert parse_address("unix:/tmp/x.sock") == ("unix", "/tmp/x.sock")
    for bad in ("http:x", "tcp:nohost", "unix:", "tcp:h:p", "tcp:h:-1"):
        with pytest.raises(ValueError):
            parse_address(bad)


def test_listen_connect_tcp_ephemeral():
    listener, addr = listen("tcp:127.0.0.1:0")
    assert addr.startswith("tcp:127.0.0.1:") and not addr.endswith(":0")
    results = {}

    def _accept():
        sock, _ = listener.accept()
        results["header"], _ = recv_msg(sock)
        sock.close()

    t = threading.Thread(target=_accept)
    t.start()
    client = connect(addr, timeout=10)
    send_msg(client, {"hello": True})
    t.join(10)
    assert results["header"]["hello"] is True
    client.close(), listener.close()


# --------------------------------------------------------------------------
# membership bookkeeping
# --------------------------------------------------------------------------


def test_membership_events_to_trace():
    ev = MembershipEvents()
    t0 = 100.0
    ev.record_join("a", t0)
    ev.record_join("b", t0 + 0.05)
    ev.record_response("a", 12.0)
    ev.record_leave("b", t0 + 0.2)
    assert ev.live() == ("a",)
    assert ev.seen() == ("a", "b")
    tr = ev.trace()
    assert tr.N == 2
    assert tr.join_ms[0] == 0.0 and tr.join_ms[1] == pytest.approx(50.0)
    # "a" responded (12 ms), "b" left before ever responding
    assert tr.mask().tolist() == [True, False]
    # rejoin clears the leave
    ev.record_join("b", t0 + 0.3)
    assert set(ev.live()) == {"a", "b"}


# --------------------------------------------------------------------------
# real worker processes (one pool for the whole module)
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pool():
    with LocalPool(workers=POOL_WORKERS) as p:
        yield p


def _problem(scheme, rng):
    if scheme.batch > 1:
        A = scheme.base.random(rng, (scheme.batch, 8, 8))
        B = scheme.base.random(rng, (scheme.batch, 8, 8))
    else:
        A = scheme.base.random(rng, (8, 8))
        B = scheme.base.random(rng, (8, 8))
    return A, B


def test_capability_handshake(pool):
    caps = pool.master.worker_caps()
    assert len(caps) >= 1
    for c in caps.values():
        assert c["device"] in ("cpu", "gpu", "tpu")
        assert c["rings"]["p2_max_e"] == 32
        assert "entries" in c["autotune"]


def test_pool_matches_local_and_multiplexes_shares(pool):
    # N=8 scheme over a 4-process pool: shares multiplex round-robin
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=8, straggler_budget=2)
    scheme = plan(spec).instantiate()
    assert scheme.N > POOL_WORKERS
    rng = np.random.default_rng(0)
    A, B = _problem(scheme, rng)
    be = PoolBackend(pool)
    C = coded_matmul(A, B, scheme, backend=be)
    C_local = coded_matmul(A, B, scheme, backend="local")
    np.testing.assert_array_equal(np.asarray(C), np.asarray(C_local))
    stats = be.last_stats
    assert stats.dispatched == tuple(range(scheme.N))
    assert len(stats.live_idx) == scheme.R
    assert set(stats.workers) <= set(range(POOL_WORKERS))


def test_pool_respects_mask_subset(pool):
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=8, straggler_budget=4)
    scheme = plan(spec).instantiate()
    rng = np.random.default_rng(1)
    A, B = _problem(scheme, rng)
    live = rng.choice(scheme.N, size=scheme.R, replace=False)
    mask = np.zeros(scheme.N, dtype=bool)
    mask[live] = True
    be = PoolBackend(pool)
    C = coded_matmul(A, B, scheme, backend=be, mask=jnp.asarray(mask))
    C_local = coded_matmul(A, B, scheme, backend="local")
    np.testing.assert_array_equal(np.asarray(C), np.asarray(C_local))
    assert be.last_stats.dispatched == tuple(sorted(int(i) for i in live))


def test_pool_secure_scheme_keyed_bit_identical(pool):
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=8, privacy_t=1)
    scheme = plan(spec).instantiate()
    rng = np.random.default_rng(2)
    A, B = _problem(scheme, rng)
    be = PoolBackend(pool)
    C = coded_matmul(A, B, scheme, backend=be, key=KEY)
    C_local = coded_matmul(A, B, scheme, backend="local", key=KEY)
    np.testing.assert_array_equal(np.asarray(C), np.asarray(C_local))


def test_scheduler_concurrent_requests_and_plan_cache(pool):
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=6, straggler_budget=2)
    rng = np.random.default_rng(3)
    with PoolScheduler(pool.master, max_queue=16, max_inflight=3) as sched:
        futs, oracles = [], []
        for _ in range(6):
            A = Z32.random(rng, (8, 8))
            B = Z32.random(rng, (8, 8))
            oracles.append(np.asarray(Z32.matmul(A, B)))
            futs.append(sched.submit(A, B, spec=spec))
        for fut, want in zip(futs, oracles):
            np.testing.assert_array_equal(np.asarray(fut.result(120)), want)
        assert sched.stats.completed == 6
        assert sched.stats.plan_cache_misses == 1
        assert sched.stats.plan_cache_hits == 5


def test_scheduler_admission_control(pool):
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=4)
    scheme = plan(spec).instantiate()
    rng = np.random.default_rng(4)
    A, B = _problem(scheme, rng)
    oracle = np.asarray(Z32.matmul(A, B))
    # park the workers so the queue actually fills
    for wid in pool.master.live_workers():
        pool.master.task_delay_ms[wid] = 150.0
    try:
        with PoolScheduler(pool.master, max_queue=1, max_inflight=1) as sched:
            f1 = sched.submit(A, B, scheme=scheme)
            time.sleep(0.05)  # let the dispatcher pick f1 up
            f2 = sched.submit(A, B, scheme=scheme)
            with pytest.raises(SchedulerSaturated):
                sched.submit(A, B, scheme=scheme)
                sched.submit(A, B, scheme=scheme)
            assert sched.stats.rejected >= 1
            np.testing.assert_array_equal(np.asarray(f1.result(120)), oracle)
            np.testing.assert_array_equal(np.asarray(f2.result(120)), oracle)
    finally:
        pool.master.task_delay_ms.clear()


def test_submit_arg_validation(pool):
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=4)
    with PoolScheduler(pool.master) as sched:
        with pytest.raises(ValueError):
            sched.submit(None, None)
        with pytest.raises(ValueError):
            sched.submit(None, None, spec=spec, scheme=object())


# --------------------------------------------------------------------------
# failure injection: real SIGKILL, mid-request (dedicated pool — it shrinks)
# --------------------------------------------------------------------------


def test_sigkill_n_minus_r_workers_mid_request_still_decodes():
    workers = 5
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=workers,
                       straggler_budget=2)
    p = plan(spec, objective="threshold")
    rank = max(range(len(p.candidates)),
               key=lambda i: p.candidates[i].costs.R)
    scheme = p.instantiate(rank)
    kill = scheme.N - scheme.R
    assert kill >= 1, "need a scheme with R < N for the kill to matter"
    rng = np.random.default_rng(5)
    A, B = _problem(scheme, rng)
    oracle = np.asarray(coded_matmul(A, B, scheme, backend="local"))
    with LocalPool(workers=workers) as fresh:
        be = PoolBackend(fresh)
        # warm round: workers jit their ring matmul before the race
        np.testing.assert_array_equal(
            np.asarray(coded_matmul(A, B, scheme, backend=be)), oracle
        )
        for wid in fresh.master.live_workers():
            fresh.master.task_delay_ms[wid] = 400.0
        result = {}

        def _request():
            try:
                result["C"] = np.asarray(coded_matmul(A, B, scheme, backend=be))
            except Exception as e:  # pragma: no cover - surfaced in assert
                result["err"] = e

        t = threading.Thread(target=_request)
        t.start()
        time.sleep(0.1)  # tasks dispatched; every worker is parked
        assert len(fresh.kill(kill)) == kill
        t.join(timeout=120)
        assert not t.is_alive(), "request hung after SIGKILL"
        assert "err" not in result, f"request failed: {result.get('err')!r}"
        np.testing.assert_array_equal(result["C"], oracle)
        assert fresh.alive_count() == workers - kill
        # the membership log saw the deaths as real leave events
        assert len(fresh.master.live_workers()) == workers - kill


def test_worker_compute_error_is_retried_not_fatal(pool):
    """An ok=False worker reply is a worker failure, not a request failure:
    the share is retried once on a different worker and the request still
    decodes exactly (strictly-less-severe than SIGKILL must not be worse)."""
    spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=6, straggler_budget=2)
    scheme = plan(spec).instantiate()
    rng = np.random.default_rng(8)
    A, B = _problem(scheme, rng)
    oracle = np.asarray(coded_matmul(A, B, scheme, backend="local"))
    bad = pool.master.live_workers()[0]
    pool.master.task_fail_wids.add(bad)
    try:
        C, stats = pool.master.execute(scheme, A, B, timeout=120)
        np.testing.assert_array_equal(np.asarray(C), oracle)
    finally:
        pool.master.task_fail_wids.clear()


def test_heartbeat_timeout_detects_stalled_worker():
    """A SIGSTOPped worker keeps its socket open — only the heartbeat
    timeout can unmask it.  The monitor must wake the blocked reader
    (socket shutdown, not close), record the leave, and re-dispatch the
    stalled worker's shares so the request completes."""
    import signal as _signal

    with LocalPool(workers=3, heartbeat_s=0.2, heartbeat_timeout=1.5) as fresh:
        spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=3,
                           straggler_budget=0)
        # zero slack (R == N): the planner's default pick at N=3 is plain
        # replication with R=1, which completes off the two healthy
        # workers without ever needing the stall unmasked — this test is
        # only meaningful when the stalled worker's share is required
        p = plan(spec, objective="threshold")
        rank = max(range(len(p.candidates)),
                   key=lambda i: p.candidates[i].costs.R)
        scheme = p.instantiate(rank)
        assert scheme.R == scheme.N == 3
        rng = np.random.default_rng(9)
        A, B = _problem(scheme, rng)
        oracle = np.asarray(coded_matmul(A, B, scheme, backend="local"))
        # warm round so the stall is the only slow thing left
        fresh.execute(scheme, A, B, timeout=120)
        victim = fresh.procs[0]
        os.kill(victim.pid, _signal.SIGSTOP)
        try:
            C, stats = fresh.execute(scheme, A, B, timeout=120)
            np.testing.assert_array_equal(np.asarray(C), oracle)
            # the stall was detected as a real leave event
            assert len(fresh.master.live_workers()) == 2
        finally:
            os.kill(victim.pid, _signal.SIGCONT)


def test_pool_trace_reflects_membership():
    with LocalPool(workers=2) as fresh:
        spec = ProblemSpec(t=8, r=8, s=8, n=1, ring=Z32, N=2)
        scheme = plan(spec).instantiate()
        rng = np.random.default_rng(6)
        A, B = _problem(scheme, rng)
        fresh.execute(scheme, A, B)
        tr = fresh.master.trace()
        assert tr.N == 2
        assert tr.mask().all()  # both responded
        fresh.kill(1)
        deadline = time.time() + 30
        while len(fresh.master.live_workers()) > 1:
            assert time.time() < deadline, "death never detected"
            time.sleep(0.05)
        tr = fresh.master.trace()
        assert np.isfinite(tr.leave_ms).sum() == 1
