"""Wire-transport + PoolConfig tests: the PR-7 compression/launcher layer.

Covers, without spawning a pool (cheap, no jax):
- bit-packing round-trips at every width 1..64 (+ the zero-width case);
- the codec negotiation matrix, incl. the v0-peer (no ``codecs`` in the
  hello) and pinned-but-unsupported downgrades to raw;
- zlib framing on/off and the compressor-inflation guard;
- the msgpack-missing JSON header fallback;
- ``Endpoint``/``parse_hostfile``/``PoolConfig`` parsing and validation;
- the shared ``repro.stats`` histogram/merge schema.

And, against one real multi-process pool (the expensive fixture at the
bottom): pipelined streaming bit-identicality vs ``LocalSimBackend`` under
a fixed key — plain and secure schemes — plus the master's raw-vs-wire
byte accounting and the single-emission deprecation shims.
"""
import os
import socket
import threading
import warnings

import numpy as np
import pytest

from repro.dist import config as dist_config
from repro.dist.config import Endpoint, HostSpec, PoolConfig, parse_hostfile
from repro.dist import protocol
from repro.dist.protocol import (
    Channel,
    negotiate,
    pack_bits,
    recv_msg,
    send_msg,
    supported_codecs,
    unpack_bits,
)
from repro.stats import Histogram, merge_snapshots, quantile_from_hist


# --------------------------------------------------------------------------
# bit packing
# --------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.uint8, np.uint16, np.uint32, np.uint64])
def test_pack_bits_round_trips_every_width(dtype):
    rng = np.random.default_rng(0)
    max_w = np.dtype(dtype).itemsize * 8
    for width in range(1, max_w + 1):
        if width == 64:
            arr = rng.integers(0, 1 << 63, (5, 7), dtype=np.uint64)
            arr = (arr << np.uint64(1)) | np.uint64(1)  # force bit 63 high
        else:
            hi = 1 << width
            arr = rng.integers(hi >> 1, hi, (5, 7)).astype(dtype)
        payload, w = pack_bits(arr)
        assert w == width, (dtype, width, w)
        expect = (arr.size * width + 7) // 8
        # packing emits ceil(bits/8) per 64-bit lane group, allow the
        # per-row rounding of the packbits layout
        assert len(payload) <= arr.nbytes or width == max_w
        back = unpack_bits(payload, w, arr.dtype.str, arr.shape)
        np.testing.assert_array_equal(back, arr)
        assert expect <= len(payload) + 8


def test_pack_bits_zero_width_and_rejections():
    z = np.zeros((4, 4), dtype=np.uint32)
    payload, w = pack_bits(z)
    assert w == 0 and len(payload) == 0
    np.testing.assert_array_equal(
        unpack_bits(payload, 0, z.dtype.str, z.shape), z
    )
    with pytest.raises(TypeError):
        pack_bits(np.zeros(3, dtype=np.int32))  # signed: raw fallback only


# --------------------------------------------------------------------------
# negotiation
# --------------------------------------------------------------------------


def test_negotiate_matrix():
    ours = supported_codecs()
    assert ours[-1] == "raw" and "pack" in ours and "pack+zlib" in ours
    # v0 peer: advertises nothing -> raw frames, full interop
    assert negotiate(None) == "raw"
    assert negotiate([]) == "raw"
    # auto takes the strongest mutual codec
    assert negotiate(list(ours)) == ours[0]
    assert negotiate(["pack", "raw"]) == "pack"
    # pinned and mutual -> pinned; pinned but peer-unsupported -> raw
    assert negotiate(list(ours), prefer="pack") == "pack"
    assert negotiate(["raw"], prefer="pack+zlib") == "raw"
    # peer advertises something we don't speak -> raw
    assert negotiate(["pack+brotli"]) == "raw"


# --------------------------------------------------------------------------
# framing: codecs, fallbacks, v0 interop
# --------------------------------------------------------------------------


def _pipe():
    return socket.socketpair()


@pytest.mark.parametrize("codec", ["raw", "pack", "pack+zlib"])
def test_send_recv_round_trip_all_codecs(codec):
    a, b = _pipe()
    rng = np.random.default_rng(1)
    arrays = {
        "fa": rng.integers(0, 1 << 16, (6, 8, 3), dtype=np.uint32),
        "gb": rng.integers(0, 1 << 16, (8, 6, 3), dtype=np.uint32),
        # float sneaks through the codec layer via the raw fallback
        "f": rng.random((4, 4)).astype(np.float32),
    }
    raw, wire = send_msg(a, {"type": "task", "task": 7}, arrays, codec=codec)
    header, got = recv_msg(b)
    assert header == {"type": "task", "task": 7}
    for k, v in arrays.items():
        np.testing.assert_array_equal(got[k], v)
    assert raw == sum(v.nbytes for v in arrays.values())
    if codec == "raw":
        assert wire > raw  # framing overhead only
    else:
        assert wire < raw  # 16 significant bits in 32-bit carriers
    a.close(), b.close()


def test_channel_counts_raw_vs_wire_bytes():
    a, b = _pipe()
    chan = Channel(a, codec="pack+zlib")
    arr = np.arange(4096, dtype=np.uint32) % 251
    chan.send({"type": "x"}, {"v": arr})
    header, got = recv_msg(b)
    np.testing.assert_array_equal(got["v"], arr)
    assert chan.raw_out == arr.nbytes
    assert chan.wire_out < chan.raw_out
    a.close(), b.close()


def test_v0_raw_frames_byte_identical_manifest():
    """codec='raw' must emit the v0 3-element manifest (old peers index
    entries positionally)."""
    a, b = _pipe()
    arr = np.arange(12, dtype=np.uint32).reshape(3, 4)
    send_msg(a, {"type": "t"}, {"h": arr}, codec="raw")
    raw = protocol._recv_frame(b)
    import msgpack

    header = msgpack.unpackb(raw[1:], raw=False)
    assert header["_arrays"] == [["h", arr.dtype.str, [3, 4]]]
    a.close(), b.close()


def test_json_header_fallback_when_msgpack_missing(monkeypatch):
    monkeypatch.setattr(protocol, "_HAVE_MSGPACK", False)
    a, b = _pipe()
    arr = np.arange(8, dtype=np.uint16)
    send_msg(a, {"type": "t", "n": 3}, {"h": arr}, codec="pack")
    header, got = recv_msg(b)
    assert header == {"type": "t", "n": 3}
    np.testing.assert_array_equal(got["h"], arr)
    a.close(), b.close()


def test_mixed_codec_handshake_with_v0_peer():
    """A master negotiating against a v0 hello (no ``codecs`` key) must
    fall back to raw frames the old worker can parse."""
    from repro.dist.master import Master

    master = Master(address="tcp:127.0.0.1:0")
    try:
        kind, (host, port) = protocol.parse_address(master.address)
        sock = socket.create_connection((host, port))
        # a v0 worker's hello: no codecs, no streaming capability
        send_msg(sock, {"type": "hello", "name": "v0", "pid": 1})
        master.wait_for_workers(1, timeout=10)
        assert master.worker_codecs() == {0: "raw"}

        # echo over the raw channel: wire bytes == raw bytes + framing
        def _serve_echo():
            header, arrays = recv_msg(sock)
            send_msg(sock, {"type": "echo_reply", "seq": header["seq"]},
                     arrays)

        t = threading.Thread(target=_serve_echo, daemon=True)
        t.start()
        out = master.echo(1024, timeout=10)
        assert out["wire_bytes"] >= out["raw_bytes"] > 0
        sock.close()
    finally:
        master.close()


# --------------------------------------------------------------------------
# Endpoint / hostfile / PoolConfig
# --------------------------------------------------------------------------


def test_endpoint_parse_and_str():
    ep = Endpoint.parse("tcp:10.0.0.4:7777")
    assert (ep.kind, ep.host, ep.port) == ("tcp", "10.0.0.4", 7777)
    assert str(ep) == "tcp:10.0.0.4:7777"
    assert Endpoint.parse(ep) is ep  # idempotent
    u = Endpoint.parse("unix:/tmp/x.sock")
    assert (u.kind, u.path) == ("unix", "/tmp/x.sock")
    with pytest.raises(ValueError):
        Endpoint.parse("bogus")


def test_parse_hostfile_literal_and_errors(tmp_path):
    text = "# comment\n10.0.0.4 slots=8\n10.0.0.5 slots=2 port=7777\n"
    hosts = parse_hostfile(text)
    assert hosts == (
        HostSpec("10.0.0.4", slots=8),
        HostSpec("10.0.0.5", slots=2, port=7777),
    )
    f = tmp_path / "hosts.txt"
    f.write_text(text)
    assert parse_hostfile(str(f)) == hosts
    with pytest.raises(ValueError):
        parse_hostfile("")  # empty
    with pytest.raises(ValueError):
        parse_hostfile("h1 gpus=4")  # unknown option


def test_pool_config_validation_and_overrides():
    cfg = PoolConfig(workers=3, transport="pack+zlib",
                     endpoint="tcp:127.0.0.1:0")
    assert isinstance(cfg.endpoint, Endpoint)
    assert cfg.total_workers == 3 and not cfg.multi_host
    assert cfg.with_(workers=5).workers == 5
    with pytest.raises(ValueError):
        PoolConfig(transport="gzip9")
    multi = PoolConfig.from_hostfile("10.0.0.4 slots=2\n10.0.0.5 slots=2")
    assert multi.total_workers == 4 and multi.multi_host
    assert multi.endpoint.kind == "tcp"


def test_pool_config_from_env_legacy_warns_once(monkeypatch):
    monkeypatch.setenv("REPRO_POOL_WORKERS", "6")
    monkeypatch.delenv("REPRO_DIST_WORKERS", raising=False)
    dist_config._WARNED.discard("REPRO_POOL_WORKERS")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert PoolConfig.from_env().workers == 6
        assert PoolConfig.from_env().workers == 6
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1 and "REPRO_POOL_WORKERS" in str(dep[0].message)
    # the modern var wins over the legacy one
    monkeypatch.setenv("REPRO_DIST_WORKERS", "2")
    assert PoolConfig.from_env().workers == 2


# --------------------------------------------------------------------------
# shared stats schema
# --------------------------------------------------------------------------


def test_histogram_snapshot_and_quantiles():
    h = Histogram((1.0, 10.0, float("inf")))
    assert h.quantile(0.5) is None  # empty
    for v in (0.5, 0.7, 5.0, 100.0):
        h.observe(v)
    snap = h.snapshot("x_ms")
    assert snap["x_ms_hist"] == {"<=1": 2, "<=10": 1, "inf": 1}
    assert snap["x_ms_p50"] == 1.0
    # the open bucket clamps to the largest finite bound (JSON-safe)
    assert snap["x_ms_p99"] == 10.0
    assert quantile_from_hist(snap["x_ms_hist"], 0.75) == 10.0


def test_merge_snapshots_sums_and_recomputes():
    a = {"completed": 2, "x_ms_hist": {"<=1": 1, "inf": 0}, "x_ms_p50": 1.0,
         "label": "a", "flag": False}
    b = {"completed": 3, "x_ms_hist": {"<=1": 0, "inf": 3}, "x_ms_p50": None,
         "label": "b", "flag": True, "only_b": 7}
    m = merge_snapshots(a, b)
    assert m["completed"] == 5 and m["only_b"] == 7
    assert m["x_ms_hist"] == {"<=1": 1, "inf": 3}
    assert m["x_ms_p50"] == 1.0  # clamped to largest finite bound
    assert m["label"] == "a" and m["flag"] is True


def test_merge_snapshots_passes_quantiles_through_without_hist():
    """Regression: a precomputed *_p50/*_p99 whose *_hist appears in no
    snapshot must survive the merge (first occurrence), not vanish."""
    m = merge_snapshots({"wait_ms_p50": 4.0}, {})
    assert m == {"wait_ms_p50": 4.0}
    # first occurrence wins among passthroughs (quantiles don't add)
    m = merge_snapshots({"wait_ms_p99": 9.0}, {"wait_ms_p99": 50.0})
    assert m == {"wait_ms_p99": 9.0}
    # ... but a histogram anywhere still triggers the recompute path
    m = merge_snapshots(
        {"x_ms_p50": 99.0}, {"x_ms_hist": {"<=1": 3, "inf": 0}}
    )
    assert m["x_ms_p50"] == 1.0
    # mixed: recomputed key and passthrough key coexist
    m = merge_snapshots(
        {"x_ms_hist": {"<=1": 1}, "x_ms_p50": 7.0, "wait_ms_p50": 4.0}, {}
    )
    assert m["x_ms_p50"] == 1.0 and m["wait_ms_p50"] == 4.0


# --------------------------------------------------------------------------
# pipelined streaming vs LocalSimBackend (one real pool, shared)
# --------------------------------------------------------------------------


SIZE = 32


@pytest.fixture(scope="module")
def stream_pool():
    from repro.dist import LocalPool

    cfg = PoolConfig(workers=3, transport="pack+zlib",
                     stream_chunk_bytes=2048)
    with LocalPool(config=cfg) as pool:
        yield pool


def _scheme_for(privacy_t=0):
    import jax

    from repro.cdmm import ProblemSpec, plan
    from repro.core import make_ring

    ring = make_ring(2, 16, ())
    spec = ProblemSpec(t=SIZE, r=SIZE, s=SIZE, n=1, ring=ring, N=4,
                       straggler_budget=1, privacy_t=privacy_t)
    scheme = plan(spec, objective="threshold").instantiate()
    rng = np.random.default_rng(7)
    A = ring.random(rng, (SIZE, SIZE))
    B = ring.random(rng, (SIZE, SIZE))
    key = jax.random.PRNGKey(5) if privacy_t else None
    return ring, scheme, A, B, key


@pytest.mark.parametrize("privacy_t", [0, 1])
def test_streaming_bit_identical_to_local_backend(stream_pool, privacy_t):
    """Chunked share transfer accumulates partial products exactly: the
    pool decode equals LocalSimBackend bit for bit under a fixed key, for
    plain and secure schemes alike."""
    from repro.cdmm import coded_matmul

    ring, scheme, A, B, key = _scheme_for(privacy_t)
    C_pool, st = stream_pool.execute(scheme, A, B, key=key, timeout=180)
    C_local = coded_matmul(A, B, scheme, backend="local", key=key)
    np.testing.assert_array_equal(np.asarray(C_pool), np.asarray(C_local))
    if privacy_t == 0:
        np.testing.assert_array_equal(
            np.asarray(C_pool), np.asarray(ring.matmul(A, B))
        )
    # compressed transport put fewer bytes on the wire than the payloads
    assert st.bytes_out < st.raw_bytes_out
    assert st.codecs == ("pack+zlib",)


def test_master_stats_schema_and_byte_accounting(stream_pool):
    snap = stream_pool.stats()
    for k in ("requests", "completed", "failed", "redispatched",
              "bytes_out", "raw_bytes_out", "bytes_in", "raw_bytes_in",
              "workers_live", "wall_ms_hist", "wall_ms_p50",
              "time_to_R_ms_hist", "time_to_R_ms_p99"):
        assert k in snap, k
    assert snap["completed"] >= 1
    assert 0 < snap["bytes_out"] < snap["raw_bytes_out"]
    assert 0 < snap["bytes_in"] < snap["raw_bytes_in"]


def test_local_pool_positional_args_warn_once():
    from repro.dist.master import LocalPool, _LEGACY_POOL_ARGS

    assert _LEGACY_POOL_ARGS[0] == "workers"
    dist_config._WARNED.discard("LocalPool-positional")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        p = LocalPool(2)
        try:
            assert p.config.workers == 2
        finally:
            p.close()
        p = LocalPool(2)
        p.close()
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)
           and "positional" in str(x.message)]
    assert len(dep) == 1
