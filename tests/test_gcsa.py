"""General-(u, v, w, kappa) GCSA: exactness across the parameter grid,
any-R recovery, bit-exact degenerations (kappa = n -> CSA, L = 1 -> EP
threshold), the singular-system decode guards, and the audited cost model
pinned against the executable code's true share shapes.

Separate module (not test_codes.py) on purpose: the eager decode paths
compile many programs and the suite-wide live-XLA-program bound is
enforced at module boundaries (see tests/conftest.py).
"""
import itertools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    CSACode,
    GCSACode,
    gcsa_cost_model,
    gr_solve,
    make_ring,
)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(7)


# ------------------------------------------------------------ general GCSA


def batch_ref(ring, As, Bs):
    return jax.vmap(ring.matmul)(As, Bs)


GCSA_CASES = [
    # (ring args, u, v, w, kappa, L, N) — R = uvw(L + kappa - 1) + w - 1
    ((2, 16, (4,)), 2, 2, 1, 1, 2, 10),   # R = 8: inner 2x2 split, per-product poles
    ((2, 16, (4,)), 1, 1, 2, 2, 2, 8),    # R = 7: MatDot inner, one kappa-group
    ((2, 16, (4,)), 2, 1, 2, 1, 2, 12),   # R = 9: asymmetric inner split
    ((2, 16, (4,)), 1, 1, 1, 2, 4, 6),    # R = 5: CSA point via the general path
    ((2, 8, (5,)), 2, 2, 2, 1, 2, 18),    # R = 17: full 3-axis inner split
    ((3, 2, (3,)), 2, 2, 1, 1, 2, 9),     # R = 8: odd p
]


@pytest.mark.parametrize("ringargs,u,v,w,kappa,L,N", GCSA_CASES)
def test_gcsa_general_exact(ringargs, u, v, w, kappa, L, N, rng):
    ring = make_ring(*ringargs)
    code = GCSACode(ring, L=L, N=N, u=u, v=v, w=w, kappa=kappa)
    assert code.R == u * v * w * (L + kappa - 1) + w - 1
    As = ring.random(rng, (L, 4, 4))
    Bs = ring.random(rng, (L, 4, 4))
    Cs = code.run(As, Bs)
    assert np.array_equal(np.asarray(Cs), np.asarray(batch_ref(ring, As, Bs)))


def test_gcsa_general_any_R_subset(rng):
    ring = make_ring(2, 16, (4,))
    code = GCSACode(ring, L=2, N=8, u=1, v=1, w=2, kappa=2)  # R = 7
    As = ring.random(rng, (2, 4, 4))
    Bs = ring.random(rng, (2, 4, 4))
    H = code.worker_compute(code.encode_a(As), code.encode_b(Bs))
    expect = np.asarray(batch_ref(ring, As, Bs))

    @jax.jit
    def dec(idx):
        return code.decode(jnp.take(H, idx, axis=0), idx)

    for subset in itertools.combinations(range(8), 7):
        Cs = dec(jnp.asarray(subset, dtype=jnp.int32))
        assert np.array_equal(np.asarray(Cs), expect), subset


def test_gcsa_general_encode_at_matches_master(rng):
    ring = make_ring(2, 16, (4,))
    code = GCSACode(ring, L=2, N=10, u=2, v=2, w=1, kappa=1)
    As = ring.random(rng, (2, 4, 4))
    Bs = ring.random(rng, (2, 4, 4))
    FA, GB = code.encode_a(As), code.encode_b(Bs)
    for i in range(code.N):
        assert np.array_equal(
            np.asarray(code.encode_a_at(As, i)), np.asarray(FA[i])
        ), i
        assert np.array_equal(
            np.asarray(code.encode_b_at(Bs, i)), np.asarray(GB[i])
        ), i


def test_gcsa_kappa_n_reduces_to_csa_bitwise(rng):
    """(u, v, w) = (1, 1, 1), kappa = n must BE the CSA code: identical
    shares and identical decodes, symbol for symbol."""
    ring = make_ring(2, 16, (4,))
    gen = GCSACode(ring, L=3, N=8, kappa=3)
    csa = CSACode(ring, L=3, N=8)
    assert gen.R == csa.R == 5
    As = ring.random(rng, (3, 4, 4))
    Bs = ring.random(rng, (3, 4, 4))
    FA, GB = gen.encode_a(As), gen.encode_b(Bs)
    assert np.array_equal(np.asarray(FA), np.asarray(csa.encode_a(As)))
    assert np.array_equal(np.asarray(GB), np.asarray(csa.encode_b(Bs)))
    H = gen.worker_compute(FA, GB)
    idx = jnp.asarray([0, 2, 3, 5, 7], dtype=jnp.int32)
    assert np.array_equal(
        np.asarray(gen.decode(jnp.take(H, idx, axis=0), idx)),
        np.asarray(csa.decode(jnp.take(H, idx, axis=0), idx)),
    )


def test_gcsa_degenerate_L1_is_single_ep(rng):
    """L = 1 collapses the outer Cauchy structure: R = uvw + w - 1, the EP
    threshold, and the single product still decodes exactly."""
    ring = make_ring(2, 16, (4,))
    code = GCSACode(ring, L=1, N=8, u=2, v=1, w=2, kappa=1)
    assert code.R == 5  # = R_EP(2, 1, 2)
    As = ring.random(rng, (1, 4, 4))
    Bs = ring.random(rng, (1, 4, 4))
    Cs = code.run(As, Bs)
    assert np.array_equal(np.asarray(Cs), np.asarray(batch_ref(ring, As, Bs)))


def test_gcsa_kappa_1_threshold(rng):
    """kappa = 1 is the per-product-poles end: R = uvw * L + w - 1."""
    ring = make_ring(2, 16, (4,))
    code = GCSACode(ring, L=4, N=8, kappa=1)
    assert code.R == 4
    As = ring.random(rng, (4, 3, 3))
    Bs = ring.random(rng, (4, 3, 3))
    Cs = code.run(As, Bs)
    assert np.array_equal(np.asarray(Cs), np.asarray(batch_ref(ring, As, Bs)))


def test_gcsa_validates_parameters():
    ring = make_ring(2, 16, (4,))
    with pytest.raises(ValueError, match="divide"):
        GCSACode(ring, L=4, N=16, kappa=3)
    with pytest.raises(ValueError, match="R="):
        GCSACode(ring, L=2, N=10, u=2, v=2, w=1, kappa=2)  # R = 12 > 10


# ------------------------------------------------- singular-system guards


def test_gr_solve_singular_raises(rng):
    """A system with no unit pivot must raise, not silently 'invert' a
    non-unit (argmax over an all-False mask selects row 0)."""
    ring = make_ring(2, 16, (3,))
    n = 3
    M = np.asarray(ring.random(rng, (n, n))).astype(np.uint32)
    for i in range(n):
        M[i, i, 0] |= 1
        for j in range(i + 1, n):
            M[i, j] = 0
    M[:, 1] = M[:, 0]  # duplicate column => singular mod p
    Y = ring.random(rng, (n, 2))
    with pytest.raises(ValueError, match="singular"):
        gr_solve(ring, jnp.asarray(M), Y)
    # all-even (non-unit) pivot column, still singular
    M2 = np.array(M)
    M2[:, 1] = 0
    M2[1, 1, 0] = 2
    with pytest.raises(ValueError, match="singular"):
        gr_solve(ring, jnp.asarray(M2), Y)


def test_decode_duplicate_live_set_raises(rng):
    """Duplicate worker indices make the decode system singular; both CSA
    and general-GCSA decode must raise — including under jit, where the
    live set is a concrete closure constant (the decode_op seam)."""
    ring = make_ring(2, 16, (4,))
    csa = CSACode(ring, L=3, N=8)
    As = ring.random(rng, (3, 3, 3))
    Bs = ring.random(rng, (3, 3, 3))
    H = csa.worker_compute(csa.encode_a(As), csa.encode_b(Bs))
    bad = jnp.asarray([0, 0, 1, 2, 3], dtype=jnp.int32)
    with pytest.raises(ValueError, match="singular"):
        csa.decode(jnp.take(H, bad, axis=0), bad)
    with pytest.raises(ValueError, match="singular"):
        jax.jit(lambda h: csa.decode(h, bad))(jnp.take(H, bad, axis=0))
    gen = GCSACode(ring, L=2, N=8, u=1, v=1, w=2, kappa=2)  # R = 7
    As2 = ring.random(rng, (2, 4, 4))
    Bs2 = ring.random(rng, (2, 4, 4))
    Hg = gen.worker_compute(gen.encode_a(As2), gen.encode_b(Bs2))
    badg = jnp.asarray([0, 1, 2, 3, 4, 5, 5], dtype=jnp.int32)
    with pytest.raises(ValueError, match="singular"):
        gen.decode(jnp.take(Hg, badg, axis=0), badg)


# ------------------------------------------------------- GCSA cost model


def test_gcsa_cost_model_matches_true_share_shapes():
    """The audited formulas must agree with the executable code's actual
    share sizes: per worker one (tb, nl*rb) + one (nl*rb, sb) share, so
    per-product upload is N(tb*rb + rb*sb)/kappa base elements at
    m_eff = 1, and the worker contraction runs over nl*rb rows."""
    t = r = s = 8
    for (u, v, w, kappa, L) in [(2, 2, 1, 1, 2), (1, 1, 2, 2, 4), (1, 1, 1, 4, 4)]:
        nl = L // kappa
        tb, rb, sb = t // u, r // w, s // v
        N = u * v * w * (L + kappa - 1) + w - 1  # minimal N = R
        c = gcsa_cost_model(t, r, s, u, v, w, L, kappa, N, m_eff=1.0)
        per_worker_elems = tb * (nl * rb) + (nl * rb) * sb
        assert c.upload * L == N * per_worker_elems, (u, v, w, kappa)
        assert c.worker_ops * L == tb * (nl * rb) * sb, (u, v, w, kappa)
        assert c.download * L == c.R * tb * sb, (u, v, w, kappa)


def test_gcsa_cost_model_paper_points():
    """Pin R and the per-product costs at Table-1 comparison points.

    At (u=v=w=1, kappa=n) GCSA's per-product upload must equal the plain
    per-product upload (t*r + r*s scaled by N*m_eff/n) — the batch is
    amortized across the group, NOT paid once per product (the pre-audit
    formulas multiplied upload/encode/worker by an extra n/kappa)."""
    t = r = s = 64
    n, N, m = 4, 16, 4.0
    c = gcsa_cost_model(t, r, s, 1, 1, 1, n, n, N, m)
    assert c.R == 2 * n - 1
    assert c.upload == N * (t * r + r * s) * m / n
    assert c.encode_ops == N * (t * r + r * s) * m**2
    assert c.worker_ops == t * r * s * m**2 / n
    assert c.decode_ops == c.R**2 * t * s * m**2 / n
    # kappa = 1: per-product poles, R = uvw*n + w - 1, no group amortization
    c1 = gcsa_cost_model(t, r, s, 2, 2, 1, n, 1, N, m)
    assert c1.R == 4 * n
    tb, rb, sb = t // 2, r, s // 2
    assert c1.upload == N * (tb * rb + rb * sb) * m
    assert c1.worker_ops == tb * rb * sb * m**2
    with pytest.raises(ValueError, match="divide"):
        gcsa_cost_model(t, r, s, 1, 1, 1, 4, 3, N, m)


def test_gcsa_threshold_gap_vs_rmfe():
    """The paper's headline: R_GCSA ~ n * R_RMFE at matched partition."""
    from repro.core import ep_cost_model

    for n in (2, 4, 8):
        for (u, v, w) in [(1, 1, 1), (2, 2, 2)]:
            g = gcsa_cost_model(64, 64, 64, u, v, w, n, n, 64, 4.0)
            b = ep_cost_model(64, 64, 64, u, v, w, 64, 4.0, batch=n)
            uvw = u * v * w
            assert g.R == uvw * (2 * n - 1) + w - 1
            assert b.R == uvw + w - 1
            assert g.R / b.R >= n  # at least the 1/n headline factor
