"""Per-architecture smoke tests: reduced config, one train + decode step on CPU.

Asserts output shapes and absence of NaNs (assignment requirement f).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_shape
from repro.models import build_model
from repro.runtime import materialize

ARCH_NAMES = sorted(ARCHS)


def make_batch(api, shape, rng):
    specs = api.batch_specs(shape)
    out = {}
    for k, ps in specs.items():
        if ps.dtype == jnp.int32:
            hi = api.cfg.vocab_size
            out[k] = jnp.asarray(rng.integers(0, hi, ps.shape, dtype=np.int64), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(ps.shape), ps.dtype)
    return out


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(5)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch, rng):
    cfg = ARCHS[arch].smoke()
    api = build_model(cfg)
    params = materialize(api.param_specs, jax.random.PRNGKey(0))
    shape = smoke_shape("train")
    batch = make_batch(api, shape, rng)

    @jax.jit
    def step(params, batch):
        (loss, metrics), grads = jax.value_and_grad(api.loss_fn, has_aux=True)(
            params, batch
        )
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        return loss, gnorm

    loss, gnorm = step(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert np.isfinite(float(gnorm)), (arch, float(gnorm))
    # random init ~> loss near log(vocab)
    assert 0.0 < float(loss) < 2 * np.log(cfg.vocab_size) + 1


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode_step(arch, rng):
    cfg = ARCHS[arch].smoke()
    api = build_model(cfg)
    params = materialize(api.param_specs, jax.random.PRNGKey(0))
    shape = smoke_shape("decode")
    cache = materialize(api.cache_decl(shape), jax.random.PRNGKey(1))
    cache = jax.tree.map(jnp.zeros_like, cache)
    if isinstance(cache, dict) and "len" in cache:
        cache["len"] = jnp.asarray(3, jnp.int32)  # pretend 3 tokens prefilled
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (shape.global_batch, 1)), jnp.int32)}

    @jax.jit
    def step(params, cache, batch):
        return api.decode_fn(params, cache, batch)

    logits, new_cache = step(params, cache, batch)
    assert logits.shape == (shape.global_batch, 1, cfg.vocab_size), arch
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32))), arch
    # cache must advance
    if isinstance(new_cache, dict) and "len" in new_cache:
        assert int(new_cache["len"]) == 4


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_prefill(arch, rng):
    cfg = ARCHS[arch].smoke()
    api = build_model(cfg)
    params = materialize(api.param_specs, jax.random.PRNGKey(0))
    shape = smoke_shape("prefill")
    batch = make_batch(api, shape, rng)
    logits = jax.jit(api.prefill_fn)(params, batch)
    assert logits.shape[0] == shape.global_batch and logits.shape[1] == 1
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32))), arch
