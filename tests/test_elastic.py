"""Elastic backend: bit-identicality under randomized membership traces,
straggler edge cases, decode-operator caching, stream rescale.

The load-bearing property: for EVERY registered scheme family and EVERY
valid join/leave/slowdown trace, the event-driven elastic execution decodes
from a *different* R-subset than the synchronous backends (first R arrivals
vs first R indices) and still produces the exact same bits — the any-R
decode is subset-agnostic because the arithmetic is integer-exact.
"""
import zlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import WorkerTrace, make_ring, sample_trace
from repro.core.straggler import select_workers
from repro.cdmm import (
    ElasticBackend,
    ElasticStream,
    LocalSimBackend,
    NotEnoughResponders,
    ProblemSpec,
    coded_matmul,
    expected_time_to_R,
    get_scheme,
    plan,
)
from repro.runtime.elastic import replan_batch

Z32 = make_ring(2, 32, ())

# one feasible configuration per registered family (mirrors test_api.py)
CASES = [
    ("ep", ProblemSpec(8, 8, 8, n=1, ring=make_ring(2, 32, (3,)), N=8), (2, 2, 1), 1),
    ("plain", ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8), (2, 2, 1), 1),
    ("ep_rmfe1", ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8), (2, 2, 1), 2),
    ("ep_rmfe2", ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8), (2, 2, 1), 2),
    ("batch_ep_rmfe", ProblemSpec(8, 8, 8, n=2, ring=Z32, N=8), (2, 2, 1), 2),
    ("gcsa", ProblemSpec(8, 8, 8, n=2, ring=Z32, N=8), (1, 1, 1), 2),
]


def _build(name, spec, uvw, n):
    u, v, w = uvw
    return get_scheme(name).build(spec, u, v, w, n)


def _inputs(scheme, spec, rng):
    shape_a = (spec.t, spec.r)
    shape_b = (spec.r, spec.s)
    if scheme.batch > 1:
        shape_a, shape_b = (scheme.batch, *shape_a), (scheme.batch, *shape_b)
    return scheme.base.random(rng, shape_a), scheme.base.random(rng, shape_b)


def _trace_with_R_responders(key, N, R, rng):
    """Random trace conditioned on at least R (or exactly R) responders."""
    for salt in range(100):
        tr = sample_trace(
            jax.random.fold_in(key, salt), N,
            join_spread_ms=2.0, leave_prob=0.25, slowdown_prob=0.3,
        )
        if tr.mask().sum() >= R:
            return tr
    raise AssertionError("trace sampler never produced >= R responders")


# ------------------------------------------------------------ property test


@pytest.mark.parametrize("name,spec,uvw,n", CASES, ids=[c[0] for c in CASES])
def test_elastic_bit_identical_to_local_under_random_traces(name, spec, uvw, n):
    scheme = _build(name, spec, uvw, n)
    rng = np.random.default_rng(11)
    A, B = _inputs(scheme, spec, rng)
    local = LocalSimBackend()
    # crc32, not hash(): PYTHONHASHSEED must not affect trace reproducibility
    key = jax.random.PRNGKey(zlib.crc32(name.encode()) % (2**31))
    for trial in range(3):
        tr = _trace_with_R_responders(
            jax.random.fold_in(key, trial), spec.N, scheme.R, rng
        )
        mask = jnp.asarray(tr.mask())
        eb = ElasticBackend(trace=tr)
        C_elastic = eb(scheme, A, B)
        C_local = local(scheme, A, B, mask=mask)
        np.testing.assert_array_equal(
            np.asarray(C_elastic), np.asarray(C_local),
            err_msg=f"{name} trial {trial} live={eb.last_stats.live_idx}",
        )
        # elastic decodes from the R fastest *arrivals*, sync from the first
        # R live indices — the subsets genuinely differ across trials, yet
        # the bits match; also sanity-check the virtual-time accounting
        st = eb.last_stats
        assert len(st.live_idx) == scheme.R
        assert st.time_to_R_ms <= st.time_to_all_ms
        assert st.n_responders == int(tr.mask().sum())


# --------------------------------------------------- straggler edge cases


def _ep_scheme(N=8):
    return _build("ep", CASES[0][1], (2, 2, 1), 1)


def test_exactly_R_live_decodes():
    scheme = _ep_scheme()
    rng = np.random.default_rng(0)
    A, B = _inputs(scheme, CASES[0][1], rng)
    expect = np.asarray(scheme.base.matmul(A, B))
    # exactly R responders, scattered: everyone else leaves before finishing
    live = np.zeros(scheme.N, bool)
    live[np.array([1, 3, 4, 6, 7])[: scheme.R]] = True
    assert live.sum() == scheme.R
    tr = WorkerTrace.all_live(scheme.N).restrict(live)
    C = ElasticBackend(trace=tr)(scheme, A, B)
    np.testing.assert_array_equal(np.asarray(C), expect)


def test_fewer_than_R_live_raises_not_decodes_garbage():
    scheme = _ep_scheme()
    rng = np.random.default_rng(0)
    A, B = _inputs(scheme, CASES[0][1], rng)
    live = np.zeros(scheme.N, bool)
    live[: scheme.R - 1] = True
    with pytest.raises(NotEnoughResponders, match=f"needs R={scheme.R}"):
        ElasticBackend()(scheme, A, B, mask=jnp.asarray(live))
    # contrast: the sync path would silently decode using a DEAD worker's
    # (meaningless) response — the elastic raise is the correct behavior
    idx = np.asarray(select_workers(jnp.asarray(live), scheme.R))
    assert not live[idx].all()


def test_all_live_fast_path():
    scheme = _ep_scheme()
    rng = np.random.default_rng(1)
    A, B = _inputs(scheme, CASES[0][1], rng)
    eb = ElasticBackend()  # no trace, no mask -> vectorized fast path
    C = eb(scheme, A, B)
    assert eb.last_stats.fast_path
    assert eb.last_stats.live_idx == tuple(range(scheme.R))
    np.testing.assert_array_equal(
        np.asarray(C), np.asarray(scheme.base.matmul(A, B))
    )
    # masked call must NOT take the fast path
    eb(scheme, A, B, mask=jnp.ones(scheme.N, bool))
    assert not eb.last_stats.fast_path


def test_decode_subset_cache_across_two_live_sets():
    scheme = _ep_scheme()
    rng = np.random.default_rng(2)
    A, B = _inputs(scheme, CASES[0][1], rng)
    expect = np.asarray(scheme.base.matmul(A, B))
    eb = ElasticBackend()
    m1 = np.ones(scheme.N, bool)
    m1[[0, 2]] = False
    m2 = np.ones(scheme.N, bool)
    m2[[1, 5]] = False
    C1 = eb(scheme, A, B, mask=jnp.asarray(m1))
    set1 = eb.last_stats.live_idx
    C2 = eb(scheme, A, B, mask=jnp.asarray(m2))
    set2 = eb.last_stats.live_idx
    assert set1 != set2, "the two masks must exercise different subsets"
    np.testing.assert_array_equal(np.asarray(C1), expect)
    np.testing.assert_array_equal(np.asarray(C2), expect)
    cache = scheme.__dict__["_decode_ops"]
    assert set(cache) >= {set1, set2}
    # replaying a seen live set hits the cached operator (same object, no
    # new entry) and still decodes exactly
    size = len(cache)
    op_before = cache[set1]
    C1b = eb(scheme, A, B, mask=jnp.asarray(m1))
    assert len(cache) == size and cache[set1] is op_before
    np.testing.assert_array_equal(np.asarray(C1b), expect)


def test_decode_op_validates_subset():
    scheme = _ep_scheme()
    with pytest.raises(ValueError, match="exactly R"):
        scheme.decode_op(tuple(range(scheme.R - 1)))
    with pytest.raises(ValueError, match="invalid live set"):
        scheme.decode_op((0,) * scheme.R)


# ------------------------------------------------------- planner objective


def test_time_to_R_objective_prefers_lower_threshold():
    # the R-th order statistic is monotone in R, so at fixed N the expected
    # elastic completion must rank lower-R schemes first
    assert expected_time_to_R(8, 2) < expected_time_to_R(8, 7)
    spec = ProblemSpec(16, 16, 16, n=1, ring=Z32, N=8)
    p = plan(spec, objective="time_to_R")
    scores = [c.score for c in p.candidates]
    assert scores == sorted(scores)
    Rs = [c.costs.R for c in p.candidates]
    assert p.best.costs.R == min(Rs)


def test_time_to_R_end_to_end_elastic():
    spec = ProblemSpec(16, 16, 16, n=1, ring=Z32, N=8, straggler_budget=2)
    scheme = plan(spec, objective="time_to_R").instantiate()
    rng = np.random.default_rng(3)
    A = Z32.random(rng, (16, 16))
    B = Z32.random(rng, (16, 16))
    tr = sample_trace(jax.random.PRNGKey(9), 8, slowdown_prob=0.4)
    C = coded_matmul(A, B, scheme, backend=ElasticBackend(trace=tr))
    np.testing.assert_array_equal(
        np.asarray(C), np.asarray(Z32.matmul(A, B))
    )


# ------------------------------------------------------- rescale mid-stream


def test_replan_batch_fixed():
    assert replan_batch(256, 16) == 16
    assert replan_batch(256, 15) == 18  # ceil: 15*18 >= 256
    assert replan_batch(7, 2) == 4
    with pytest.raises(ValueError, match="at least one survivor"):
        replan_batch(256, 0)
    with pytest.raises(ValueError, match="at least one survivor"):
        replan_batch(256, -3)
    with pytest.raises(ValueError, match="global_batch"):
        replan_batch(0, 4)


def test_stream_rescales_mid_stream():
    st = ElasticStream(8, 8, 8, Z32, group_size=8)
    rng = np.random.default_rng(4)
    As = Z32.random(rng, (6, 8, 8))
    Bs = Z32.random(rng, (6, 8, 8))
    expect = [np.asarray(Z32.matmul(As[i], Bs[i])) for i in range(6)]

    Cs = st.step(As, Bs, live=16)  # two groups of 8 -> per-group batch 3
    assert st.last_replan == (2, 3)
    for i in range(6):
        np.testing.assert_array_equal(np.asarray(Cs[i]), expect[i])

    Cs = st.step(As, Bs, live=9)  # workers left: one group absorbs the lot
    assert st.last_replan == (1, 6)
    for i in range(6):
        np.testing.assert_array_equal(np.asarray(Cs[i]), expect[i])

    with pytest.raises(NotEnoughResponders):
        st.step(As, Bs, live=7)
