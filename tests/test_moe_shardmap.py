"""shard_map MoE parity vs the dense reference (no drops => identical)."""
import os

import numpy as np
import pytest

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs import ARCHS  # noqa: E402
from repro.models.layers import _apply_moe_dense, apply_moe, moe_specs  # noqa: E402
from repro.runtime.sharding import axis_rules, materialize  # noqa: E402

NDEV = len(jax.devices())
needs8 = pytest.mark.skipif(NDEV < 8, reason="needs 8 devices")


@needs8
@pytest.mark.parametrize("shared", [0, 1])
def test_moe_shardmap_matches_dense(shared):
    cfg = dataclasses.replace(
        ARCHS["qwen3-moe-30b-a3b"].smoke(),
        num_experts=8, experts_per_tok=2, expert_d_ff=64,
        capacity_factor=8.0,  # no drops -> exact parity
        shared_experts=shared,
        dtype="float32",
    )
    p = materialize(moe_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)) * 0.1, jnp.float32)
    ref, aux_ref = _apply_moe_dense(p, x, cfg)
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 2, 2), ("pod", "data", "model"))
    with mesh, axis_rules(mesh):
        out, aux = jax.jit(lambda p, x: apply_moe(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-4)


@needs8
def test_moe_shardmap_grads_finite():
    cfg = dataclasses.replace(
        ARCHS["qwen3-moe-30b-a3b"].smoke(),
        num_experts=8, experts_per_tok=2, expert_d_ff=64, dtype="float32",
    )
    p = materialize(moe_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)) * 0.1, jnp.float32)
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 2, 2), ("pod", "data", "model"))

    def loss(p, x):
        out, aux = apply_moe(p, x, cfg)
        return jnp.sum(jnp.square(out)) + 0.01 * aux

    with mesh, axis_rules(mesh):
        g = jax.jit(jax.grad(loss))(p, x)
    for leaf in jax.tree.leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


@needs8
def test_moe_ep_a2a_matches_dense():
    """The all-to-all EP island == dense reference (no drops)."""
    import dataclasses as dc
    from repro.runtime.sharding import axis_rules
    cfg = dc.replace(
        ARCHS["qwen3-moe-30b-a3b"].smoke(),
        num_experts=8, experts_per_tok=2, expert_d_ff=64,
        capacity_factor=16.0, dtype="float32", shared_experts=1,
    )
    p = materialize(moe_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)) * 0.1, jnp.float32)
    ref, aux_ref = _apply_moe_dense(p, x, cfg)
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 2, 2), ("pod", "data", "model"))
    with mesh, axis_rules(mesh, {"residual_seq": "model"}):
        out, aux = jax.jit(lambda p, x: apply_moe(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-4)
