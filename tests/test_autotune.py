"""Kernel autotuner: cache round-trip, deterministic candidate enumeration,
ops.gr_matmul consulting the tuned cache, and envelope fallbacks."""
import json

import numpy as np
import pytest

from repro.core import make_ring
from repro.kernels import (
    cached_blocks,
    candidate_blocks,
    gr_matmul,
    gr_matmul_ref,
    kernel_supported,
    tune_key,
)
from repro.kernels import autotune as at
from repro.kernels import ops as kernel_ops

GR3 = make_ring(2, 32, (3,))
Z32 = make_ring(2, 32, ())


@pytest.fixture(autouse=True)
def fresh_memory_cache():
    """Each test sees the committed disk cache afresh and leaks nothing
    in-process (autotune() mutates the in-memory view, never the JSON)."""
    at.invalidate_memory_cache()
    yield
    at.invalidate_memory_cache()


# ------------------------------------------------------------- cache I/O


def test_cache_roundtrip(tmp_path):
    entries = {
        tune_key(GR3, 16, 16, 16, device="testdev"): {
            "blocks": [8, 16, 16], "us": 123.4, "tried": 5,
        },
        tune_key(Z32, 64, 64, 64, device="testdev"): {
            "blocks": [64, 64, 64], "us": 9.9, "tried": 8,
        },
    }
    path = tmp_path / "cache.json"
    at.save_cache(entries, path)
    assert at.load_cache(path) == json.loads(path.read_text())["entries"]
    assert at.load_cache(path) == entries


def test_cache_load_rejects_malformed_entries(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({
        "version": at.CACHE_VERSION,
        "entries": {"k": {"blocks": [8, 16], "us": 1.0}},  # 2-tuple: invalid
    }))
    with pytest.raises(ValueError, match="malformed"):
        at.load_cache(path)


def test_cache_version_mismatch_is_empty(tmp_path):
    path = tmp_path / "old.json"
    path.write_text(json.dumps({"version": 999, "entries": {"k": {}}}))
    assert at.load_cache(path) == {}
    assert at.load_cache(tmp_path / "missing.json") == {}


def test_committed_cache_deserializes_and_covers_tier1_points():
    """The committed JSON must stay loadable and must cover the tier-1
    ring/shape points for the device it was tuned on (mirrors the CI
    autotune-smoke --check)."""
    entries = at.load_cache()
    assert entries, "committed autotune cache is missing or empty"
    devices = {key.split("|", 1)[0] for key in entries}
    assert any(
        not at.coverage_gaps(entries, device=dev) for dev in devices
    ), f"no device in {sorted(devices)} fully covers DEFAULT_POINTS"


# ------------------------------------------------- candidate enumeration


def test_candidate_enumeration_is_deterministic():
    a = candidate_blocks(GR3, 128, 128, 128)
    b = candidate_blocks(GR3, 128, 128, 128)
    assert a == b and len(a) == len(set(a))


def test_candidates_include_static_default_and_respect_vmem():
    cands = candidate_blocks(GR3, 128, 128, 128)
    assert (128, 128, 128) in cands
    for bt, bs, br in cands:
        words = (bt * br + br * bs + bt * bs) * GR3.D + GR3.K * bt * bs
        assert words * 4 <= at.VMEM_BUDGET_BYTES, (bt, bs, br)
    # divisor-aware ordering: the first candidate wastes no padding
    bt, bs, br = cands[0]
    assert 128 % bt == 0 and 128 % bs == 0 and 128 % br == 0


def test_candidates_for_small_dims_are_single_block():
    assert candidate_blocks(GR3, 8, 8, 8) == [(8, 8, 8)]
    # ragged dims align up to 8 before enumeration
    assert candidate_blocks(GR3, 7, 5, 3) == [(8, 8, 8)]


def test_tune_key_canonicalizes_ragged_shapes():
    assert tune_key(GR3, 7, 13, 5, device="d") == tune_key(
        GR3, 8, 16, 8, device="d"
    )
    assert tune_key(GR3, 8, 8, 8, device="d") != tune_key(
        Z32, 8, 8, 8, device="d"
    )


# --------------------------------------------------- tuning + ops wiring


def test_autotune_records_and_ops_picks_cached_config(monkeypatch):
    # 24^3 is deliberately off DEFAULT_POINTS so the committed cache can
    # never mask what this test tunes in-process
    res = at.autotune(GR3, 24, 24, 24, budget=3, iters=1)
    assert res.tried <= 3 and res.blocks in candidate_blocks(GR3, 24, 24, 24)
    assert cached_blocks(GR3, 24, 24, 24) == res.blocks

    seen = {}
    real_planar = kernel_ops.gr_matmul_planar

    def spy(A, B, ring, *, bt, bs, br, interpret):
        seen["blocks"] = (bt, bs, br)
        return real_planar(A, B, ring, bt=bt, bs=bs, br=br,
                           interpret=interpret)

    monkeypatch.setattr(kernel_ops, "gr_matmul_planar", spy)
    rng = np.random.default_rng(0)
    A, B = GR3.random(rng, (24, 24)), GR3.random(rng, (24, 24))
    out = gr_matmul(A, B, GR3, interpret=True)
    assert seen["blocks"] == res.blocks
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(gr_matmul_ref(A, B, GR3))
    )


def test_explicit_blocks_override_cache():
    at.autotune(GR3, 24, 24, 24, budget=2, iters=1)
    rng = np.random.default_rng(1)
    A, B = GR3.random(rng, (24, 24)), GR3.random(rng, (24, 24))
    out = gr_matmul(A, B, GR3, blocks=(8, 8, 8), interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(gr_matmul_ref(A, B, GR3))
    )


def test_lru_survives_disk_invalidation_boundary():
    res = at.autotune(GR3, 24, 24, 24, budget=2, iters=1)
    assert cached_blocks(GR3, 24, 24, 24) == res.blocks  # LRU hit
    at.invalidate_memory_cache()
    # nothing was persisted: in-process result gone, committed cache rules
    key = tune_key(GR3, 24, 24, 24)
    assert (cached_blocks(GR3, 24, 24, 24) is None) == (
        key not in at.load_cache()
    )


def test_autotune_rejects_out_of_envelope_rings():
    with pytest.raises(ValueError, match="envelope"):
        at.autotune(make_ring(3, 2, (2,)), 8, 8, 8)


# --------------------------------------------------- fallbacks + padding


def test_gr_matmul_falls_back_outside_envelope():
    ring = make_ring(3, 2, (2,))
    assert not kernel_supported(ring)
    rng = np.random.default_rng(2)
    A, B = ring.random(rng, (6, 6)), ring.random(rng, (6, 6))
    np.testing.assert_array_equal(
        np.asarray(gr_matmul(A, B, ring)),
        np.asarray(gr_matmul_ref(A, B, ring)),
    )


def test_planar_kernel_clamps_and_pads_odd_blocks():
    """The old hard assert (T % bt == 0 ...) is gone: non-dividing and
    oversized block sizes zero-pad instead of crashing."""
    import jax.numpy as jnp

    from repro.kernels.gr_matmul import gr_matmul_planar

    rng = np.random.default_rng(3)
    A = GR3.random(rng, (20, 14))
    B = GR3.random(rng, (14, 9))
    Ap, Bp = jnp.moveaxis(A, -1, 0), jnp.moveaxis(B, -1, 0)
    ref = jnp.moveaxis(gr_matmul_ref(A, B, GR3), -1, 0)
    for blocks in [(16, 8, 128), (8, 8, 8), (256, 256, 256)]:
        bt, bs, br = blocks
        out = gr_matmul_planar(
            Ap, Bp, GR3, bt=bt, bs=bs, br=br, interpret=True
        )
        assert out.shape == ref.shape
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref), err_msg=str(blocks)
        )
