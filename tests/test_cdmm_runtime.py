"""Distributed CDMM runtime tests: shard_map workers on a multi-device mesh.

Uses 8 host platform devices (set before jax import via conftest isolation —
this file spawns a subprocess-free approach: we request the devices with
jax.config if still uninitialized, otherwise skip gracefully).
"""
import os
import sys

import numpy as np
import pytest

# must happen before jax initializes its backends
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.core import BatchEPRMFE, EPCode, make_ring  # noqa: E402
from repro.cdmm import (  # noqa: E402
    CodedQuantMatmul,
    DistributedBatchRMFE,
    DistributedEP,
    cdmm_shard_map,
)

NDEV = len(jax.devices())
needs8 = pytest.mark.skipif(NDEV < 8, reason=f"needs 8 devices, have {NDEV}")


@pytest.fixture(scope="module")
def mesh():
    return Mesh(np.array(jax.devices()[:8]).reshape(8), ("workers",))


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(11)


@needs8
def test_distributed_ep_worker_encode(mesh, rng):
    ring = make_ring(2, 32, (3,))
    code = EPCode(ring, N=8, u=2, v=2, w=1)
    dep = DistributedEP(code, "workers")
    A = ring.random(rng, (4, 4))
    B = ring.random(rng, (4, 4))
    mask = jnp.ones(8, dtype=bool)
    f = jax.jit(cdmm_shard_map(dep, mesh, "workers"))
    C = f(A, B, mask)
    np.testing.assert_array_equal(np.asarray(C), np.asarray(ring.matmul(A, B)))


@needs8
def test_distributed_ep_with_stragglers(mesh, rng):
    ring = make_ring(2, 32, (3,))
    code = EPCode(ring, N=8, u=2, v=2, w=1)  # R = 4: tolerate 4 dead workers
    dep = DistributedEP(code, "workers")
    A = ring.random(rng, (4, 4))
    B = ring.random(rng, (4, 4))
    expect = np.asarray(ring.matmul(A, B))
    f = jax.jit(cdmm_shard_map(dep, mesh, "workers"))
    for dead in [(0,), (7,), (1, 3), (0, 2, 5, 6)]:
        mask = np.ones(8, dtype=bool)
        mask[list(dead)] = False
        C = f(A, B, jnp.asarray(mask))
        np.testing.assert_array_equal(np.asarray(C), expect, err_msg=str(dead))


@needs8
def test_distributed_ep_master_encode(mesh, rng):
    ring = make_ring(2, 32, (3,))
    code = EPCode(ring, N=8, u=2, v=2, w=1)
    dep = DistributedEP(code, "workers", master_encode=True)
    A = ring.random(rng, (4, 4))
    B = ring.random(rng, (4, 4))
    mask = jnp.ones(8, dtype=bool)
    C = jax.jit(cdmm_shard_map(dep, mesh, "workers"))(A, B, mask)
    np.testing.assert_array_equal(np.asarray(C), np.asarray(ring.matmul(A, B)))


@needs8
def test_distributed_batch_rmfe(mesh, rng):
    base = make_ring(2, 32, ())
    sch = BatchEPRMFE(base, n=2, N=8, u=2, v=2, w=1)
    dsch = DistributedBatchRMFE(sch, "workers")
    As = base.random(rng, (2, 4, 4))
    Bs = base.random(rng, (2, 4, 4))
    mask = np.ones(8, dtype=bool)
    mask[[2, 6]] = False  # two stragglers
    Cs = jax.jit(cdmm_shard_map(dsch, mesh, "workers"))(As, Bs, jnp.asarray(mask))
    for i in range(2):
        np.testing.assert_array_equal(
            np.asarray(Cs[i]), np.asarray(base.matmul(As[i], Bs[i]))
        )


# ---------------------------------------------------------- quantized plane


def test_coded_quant_local_exact(rng):
    """Local (no mesh) coded int8 matmul is bit-exact vs integer reference."""
    cm = CodedQuantMatmul(N=8, axis_name=None)
    qx = rng.integers(-127, 128, (8, 16)).astype(np.int8)
    qw = rng.integers(-127, 128, (16, 8)).astype(np.int8)
    out = cm.exact_int_matmul(jnp.asarray(qx), jnp.asarray(qw))
    expect = qx.astype(np.int64) @ qw.astype(np.int64)
    np.testing.assert_array_equal(np.asarray(out, dtype=np.int64), expect)


def test_coded_quant_float_path(rng):
    cm = CodedQuantMatmul(N=8, axis_name=None)
    x = rng.standard_normal((4, 16)).astype(np.float32)
    w = rng.standard_normal((16, 8)).astype(np.float32)
    y = np.asarray(cm(jnp.asarray(x), jnp.asarray(w)))
    # int8 quantization error bound, not exactness
    ref = x @ w
    err = np.abs(y - ref) / (np.abs(ref).max() + 1e-6)
    assert err.max() < 0.05


@needs8
def test_coded_quant_spmd_with_stragglers(mesh, rng):
    cm = CodedQuantMatmul(N=8, axis_name="workers")
    qx = rng.integers(-127, 128, (8, 16)).astype(np.int8)
    qw = rng.integers(-127, 128, (16, 8)).astype(np.int8)
    expect = qx.astype(np.int64) @ qw.astype(np.int64)
    mask = np.ones(8, dtype=bool)
    mask[[1, 4, 6]] = False  # 3 dead of 8, R=4
    f = jax.jit(cdmm_shard_map(cm.exact_int_matmul, mesh, "workers"))
    out = f(jnp.asarray(qx), jnp.asarray(qw), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(out, dtype=np.int64), expect)
