"""Secure (T-private) CDMM: privacy proofs by exhaustive enumeration,
keyed-encode determinism, and planner privacy edge cases.

The privacy tests are information-theoretic, not statistical: over a small
ring every possible mask draw is enumerated, so "identically distributed"
is an exact multiset equality, not a sampling approximation.
"""
import itertools
from collections import Counter

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import make_ring
from repro.core.secure import (
    SecureBatchEPRMFE,
    SecureEP,
    SecureEPCode,
    secure_recovery_threshold,
    smallest_secure_ext,
)
from repro.cdmm import ProblemSpec, coded_matmul, plan

Z32 = make_ring(2, 32, ())
KEY = jax.random.PRNGKey(0)


def _all_elements(ring):
    """Every element of the ring as a (D,) uint32 coefficient vector."""
    for coeffs in itertools.product(range(ring.q), repeat=ring.D):
        yield np.array(coeffs, dtype=np.uint32)


def _share_tuple(FA, workers):
    """Hashable view of the given workers' shares."""
    return tuple(
        tuple(int(x) for x in np.asarray(FA[i]).ravel()) for i in workers
    )


def _all_shares(encode, A, mask_iter):
    """Materialize the (N, ...) share stack for every mask draw."""
    return [np.asarray(encode(A, jnp.asarray(Z))) for Z in mask_iter]


def _distribution(shares, workers):
    """Exact distribution (Counter) of the named workers' joint shares over
    an exhaustive mask enumeration."""
    return Counter(_share_tuple(FA, workers) for FA in shares)


# ---------------------------------------------------------------- privacy


class TestExhaustivePrivacyT1:
    """T=1 over GR(2^2, 2) (16 elements, 4 exceptional points): any single
    worker's share is exactly uniform — independent of the input — while any
    2 workers' joint shares are input-dependent."""

    ring = make_ring(2, 2, (2,))
    code = SecureEPCode(ring, N=3, u=1, v=1, w=1, T=1)
    # two distinct fixed 1x1 inputs
    A0 = jnp.asarray(np.zeros((1, 1, 2), dtype=np.uint32))
    A1 = jnp.asarray(np.array([3, 1], dtype=np.uint32).reshape(1, 1, 2))

    def _masks(self):
        for z in _all_elements(self.ring):
            yield z.reshape(1, 1, 1, 2)

    @pytest.fixture(scope="class")
    def a_shares(self):
        enc = jax.jit(self.code.encode_a_with_masks)
        return (_all_shares(enc, self.A0, self._masks()),
                _all_shares(enc, self.A1, self._masks()))

    def test_any_T_workers_learn_nothing(self, a_shares):
        s0, s1 = a_shares
        size = self.ring.q**self.ring.D  # 16
        for i in range(self.code.N):
            d0 = _distribution(s0, (i,))
            d1 = _distribution(s1, (i,))
            # identical distributions for distinct inputs...
            assert d0 == d1, f"worker {i} can distinguish the inputs"
            # ...and exactly uniform over the whole ring
            assert len(d0) == size and set(d0.values()) == {1}

    def test_T_plus_1_workers_do_learn(self, a_shares):
        s0, s1 = a_shares
        leaked = []
        for pair in itertools.combinations(range(self.code.N), 2):
            leaked.append(_distribution(s0, pair) != _distribution(s1, pair))
        # every 2-subset distinguishes the inputs (1 mask, 2 equations)
        assert all(leaked)

    def test_b_side_shares_also_uniform(self):
        size = self.ring.q**self.ring.D
        enc = jax.jit(self.code.encode_b_with_masks)
        s0 = _all_shares(enc, self.A0, self._masks())
        s1 = _all_shares(enc, self.A1, self._masks())
        for i in range(self.code.N):
            d0, d1 = _distribution(s0, (i,)), _distribution(s1, (i,))
            assert d0 == d1
            assert len(d0) == size and set(d0.values()) == {1}


class TestExhaustivePrivacyT2:
    """T=2 over GF(8) (8 elements, 8 exceptional points): any 2 workers see
    an exactly uniform joint distribution; 3 workers distinguish inputs."""

    ring = make_ring(2, 1, (3,))
    code = SecureEPCode(ring, N=6, u=1, v=1, w=1, T=2)  # R = 5 <= 6
    A0 = jnp.asarray(np.zeros((1, 1, 3), dtype=np.uint32))
    A1 = jnp.asarray(np.array([1, 0, 1], dtype=np.uint32).reshape(1, 1, 3))

    def _masks(self):
        for z0, z1 in itertools.product(_all_elements(self.ring), repeat=2):
            yield np.stack([z0, z1]).reshape(2, 1, 1, 3)

    @pytest.fixture(scope="class")
    def a_shares(self):
        enc = jax.jit(self.code.encode_a_with_masks)
        return (_all_shares(enc, self.A0, self._masks()),
                _all_shares(enc, self.A1, self._masks()))

    def test_any_2_workers_uniform(self, a_shares):
        s0, s1 = a_shares
        size = (self.ring.q**self.ring.D) ** 2  # 64 joint share values
        for pair in [(0, 1), (2, 5), (1, 4)]:
            d0, d1 = _distribution(s0, pair), _distribution(s1, pair)
            assert d0 == d1, f"workers {pair} can distinguish the inputs"
            assert len(d0) == size and set(d0.values()) == {1}

    def test_3_workers_leak(self, a_shares):
        s0, s1 = a_shares
        trio = (0, 1, 2)
        assert _distribution(s0, trio) != _distribution(s1, trio)


# ------------------------------------------------- construction invariants


def test_secure_points_exclude_zero_and_are_units():
    ring = make_ring(2, 32, (3,))
    code = SecureEPCode(ring, N=7, u=1, v=1, w=1, T=2)
    # the zero point would hand its worker an unmasked data block
    assert not np.any(np.all(code.points_np == 0, axis=1))
    for pt in code.points_np:
        assert ring.s_is_unit(pt.astype(object))


def test_secure_threshold_and_validation():
    ring = make_ring(2, 32, (4,))
    assert secure_recovery_threshold(1, 1, 1, 1) == 3
    assert secure_recovery_threshold(2, 2, 1, 2) == 11
    with pytest.raises(ValueError, match="T >= 1"):
        SecureEPCode(ring, N=8, u=1, v=1, w=1, T=0)
    with pytest.raises(ValueError, match="> N"):
        SecureEPCode(ring, N=4, u=2, v=2, w=1, T=1)  # R = 9
    # N+1 points needed: |T(Z32)| = 2 cannot host N=3 (R = 3 <= N passes)
    with pytest.raises(ValueError, match="exceptional points"):
        SecureEPCode(Z32, N=3, u=1, v=1, w=1, T=1)


def test_smallest_secure_ext_counts_the_skipped_zero():
    # 8 workers need 9 points: degree 3 (8 points) is NOT enough
    ext = smallest_secure_ext(Z32, 8)
    assert ext.p**ext.D >= 9
    # 7 workers fit in 8 points
    assert smallest_secure_ext(Z32, 7).D == 3


# ------------------------------------------------- keyed-encode determinism


def test_key_determinism_master_vs_at_worker():
    rng = np.random.default_rng(5)
    sep = SecureEP(Z32, N=8, u=1, v=2, w=1, T=1)  # R = 2*2 + 2 - 1 = 5
    A = Z32.random(rng, (4, 4))
    B = Z32.random(rng, (4, 4))
    eA = sep.embed(A)
    eB = sep.embed(B)
    key = jax.random.PRNGKey(123)
    FA = sep.code.encode_a(eA, key)
    GB = sep.code.encode_b(eB, key)
    for i in range(sep.code.N):
        np.testing.assert_array_equal(
            np.asarray(sep.code.encode_a_at(eA, i, key)), np.asarray(FA[i])
        )
        np.testing.assert_array_equal(
            np.asarray(sep.code.encode_b_at(eB, i, key)), np.asarray(GB[i])
        )
    # a different key produces different shares (masks actually used) ...
    FA2 = sep.code.encode_a(eA, jax.random.PRNGKey(124))
    assert not np.array_equal(np.asarray(FA), np.asarray(FA2))
    # ... yet decodes to the same product
    C1 = sep.run(A, B, key)
    C2 = sep.run(A, B, jax.random.PRNGKey(124))
    np.testing.assert_array_equal(np.asarray(C1), np.asarray(C2))
    np.testing.assert_array_equal(np.asarray(C1), np.asarray(Z32.matmul(A, B)))


def test_secure_requires_key():
    sep = SecureEP(Z32, N=8, u=1, v=1, w=1, T=1)
    rng = np.random.default_rng(0)
    A = Z32.random(rng, (2, 2))
    with pytest.raises(ValueError, match="key"):
        sep.code.encode_a(sep.embed(A))


def test_secure_batch_any_R_subsets():
    rng = np.random.default_rng(11)
    sb = SecureBatchEPRMFE(Z32, n=2, N=8, u=1, v=1, w=1, T=2)  # R = 5
    As = Z32.random(rng, (2, 4, 4))
    Bs = Z32.random(rng, (2, 4, 4))
    expect = [np.asarray(Z32.matmul(As[i], Bs[i])) for i in range(2)]
    for trial in range(4):
        idx = jnp.asarray(
            np.sort(rng.choice(8, size=sb.R, replace=False)), jnp.int32
        )
        Cs = sb.run(As, Bs, jax.random.PRNGKey(trial), idx)
        for i in range(2):
            np.testing.assert_array_equal(np.asarray(Cs[i]), expect[i])


# ------------------------------------------------------- planner edge cases


def test_plan_privacy_never_returns_insecure_scheme():
    for n in (1, 2):
        spec = ProblemSpec(8, 8, 8, n=n, ring=Z32, N=8, privacy_t=1)
        p = plan(spec, objective="latency")
        assert p.candidates, "secure plan must be feasible at N=8"
        assert all(c.costs.privacy_t >= 1 for c in p.candidates)
        scheme = p.instantiate()
        assert scheme.privacy_t >= 1


def test_plan_privacy_threshold_accounting():
    spec = ProblemSpec(8, 8, 8, n=1, ring=Z32, N=16, privacy_t=3)
    p = plan(spec, objective="threshold")
    # cheapest secure partition u=v=w=1: R = 2 + 2T - 1 = 7
    assert p.best.costs.R == 2 * 1 + 2 * 3 - 1


def test_plan_privacy_plus_straggler_budget_exhausts_N():
    # N - budget = 2 < 2T + 1 = 3: caught with a clear error, not an
    # infeasible plan
    with pytest.raises(ValueError, match="privacy_t"):
        plan(ProblemSpec(8, 8, 8, n=1, ring=Z32, N=4,
                         straggler_budget=2, privacy_t=1))
    with pytest.raises(ValueError, match="privacy_t"):
        plan(ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8,
                         straggler_budget=2, privacy_t=3))
    # the same budgets without privacy stay feasible
    plan(ProblemSpec(8, 8, 8, n=1, ring=Z32, N=4, straggler_budget=2))


def test_plan_privacy_respects_combined_budgets_when_feasible():
    spec = ProblemSpec(8, 8, 8, n=1, ring=Z32, N=12,
                       straggler_budget=4, privacy_t=2)
    p = plan(spec)
    assert all(c.costs.R <= 12 - 4 for c in p.candidates)
    assert all(c.costs.privacy_t >= 2 for c in p.candidates)


def test_plan_insecure_schemes_filtered_by_name_restriction():
    # explicitly requesting only insecure families under a privacy
    # requirement must fail loudly, not silently downgrade
    with pytest.raises(ValueError, match="no feasible scheme"):
        plan(ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8, privacy_t=1),
             schemes=["ep_rmfe1", "plain"])


def test_spec_validates_privacy_t():
    with pytest.raises(ValueError, match="privacy_t"):
        ProblemSpec(8, 8, 8, ring=Z32, privacy_t=-1).validate()


# ------------------------------------------------------- end-to-end seam


def test_coded_matmul_secure_fixed_key_matches_oracle():
    spec = ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8, privacy_t=1)
    scheme = plan(spec).instantiate()
    rng = np.random.default_rng(3)
    A = Z32.random(rng, (8, 8))
    B = Z32.random(rng, (8, 8))
    mask = np.ones(8, bool)
    mask[[1, 6]] = False
    C = coded_matmul(A, B, scheme, backend="local",
                     mask=jnp.asarray(mask), key=KEY)
    np.testing.assert_array_equal(
        np.asarray(C), np.asarray(Z32.matmul(A, B))
    )
