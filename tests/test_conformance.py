"""Property-based conformance suite: EVERY registered scheme family x EVERY
execution backend must decode bit-identically to the plain ``A @ B`` oracle
under randomized specs and randomized responding subsets of size R.

There is no per-scheme special-casing: a feasible configuration for each
family is discovered generically through its registered ``predict`` hook, so
any future ``register_scheme`` call is automatically covered (and the suite
fails if a family has no feasible configuration on the template grid).

hypothesis is optional, mirroring tests/test_kernels.py: the deterministic
sweep always runs; the property-based tests add randomized examples when
hypothesis is installed.  The ``ci-fast`` profile (HYPOTHESIS_PROFILE env
var) keeps the fast CI tier under budget.
"""
import os

# must happen before jax initializes its backends (shard_map backend)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import pytest  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile(
        "dev",
        max_examples=5,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.register_profile(
        "ci-fast",
        max_examples=2,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

from repro.core import make_ring  # noqa: E402
from repro.cdmm import (  # noqa: E402
    ElasticBackend,
    ShardMapBackend,
    coded_matmul,
    registered_schemes,
)
from repro.cdmm.api import ProblemSpec  # noqa: E402
from repro.kernels import kernel_supported  # noqa: E402

Z32 = make_ring(2, 32, ())
NDEV = len(jax.devices())
KEY = jax.random.PRNGKey(0)

# template grid the generic feasibility search walks: base sizes 8 with
# every partition in {1,2}^3 and packing in {1,2}.  Ordered so the plainest
# spec that serves a family wins; privacy templates come last, which keeps
# non-secure families on insecure specs (their predicts reject nothing, but
# secure families reject privacy_t=0 so they land on the privacy templates).
SPEC_TEMPLATES = [
    ProblemSpec(8, 8, 8, n=1, ring=make_ring(2, 32, (3,)), N=8),
    ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8),
    ProblemSpec(8, 8, 8, n=2, ring=Z32, N=8),
    ProblemSpec(8, 8, 8, n=1, ring=Z32, N=8, privacy_t=1),
    ProblemSpec(8, 8, 8, n=2, ring=Z32, N=8, privacy_t=1),
]
PARTITIONS = [
    (u, v, w, n) for u in (1, 2) for v in (1, 2) for w in (1, 2)
    for n in (1, 2)
]

BACKENDS = ["local", "shard_map", "elastic"]
_ELASTIC = ElasticBackend()  # shared pool across the whole suite


def find_config(fam):
    """First spec template admitting the family, with the largest-R feasible
    partition (largest R = the most interesting any-R subsets)."""
    for spec in SPEC_TEMPLATES:
        # mirror the planner's arity rule: batch families serve n>1 specs,
        # single families serve n=1 specs
        if fam.batched != (spec.n > 1):
            continue
        feasible = []
        for (u, v, w, n) in PARTITIONS:
            costs = fam.predict(spec, u, v, w, n)
            if costs is not None and costs.R <= spec.N:
                feasible.append(((u, v, w, n), costs.R))
        if feasible:
            (u, v, w, n), _ = max(feasible, key=lambda c: c[1])
            return spec, (u, v, w, n)
    return None


_SCHEMES = {}


def build_scheme(name):
    """Build (and memoize) the family's discovered configuration."""
    if name not in _SCHEMES:
        fam = registered_schemes()[name]
        found = find_config(fam)
        assert found is not None, (
            f"family {name!r} has no feasible configuration on the "
            f"conformance template grid — extend SPEC_TEMPLATES"
        )
        spec, (u, v, w, n) = found
        _SCHEMES[name] = (spec, fam.build(spec, u, v, w, n))
    return _SCHEMES[name]


def _random_problem(scheme, spec, rng, mult):
    """Random inputs at a randomized spec (template sizes x mult)."""
    t, r, s = spec.t * mult, spec.r * mult, spec.s * mult
    base = scheme.base
    if scheme.batch > 1:
        A = base.random(rng, (scheme.batch, t, r))
        B = base.random(rng, (scheme.batch, r, s))
        expect = np.stack(
            [np.asarray(base.matmul(A[i], B[i])) for i in range(scheme.batch)]
        )
    else:
        A = base.random(rng, (t, r))
        B = base.random(rng, (r, s))
        expect = np.asarray(base.matmul(A, B))
    return A, B, expect


def _run_backend(scheme, backend, A, B, mask, key, use_kernel=False):
    mask = jnp.asarray(mask)
    if backend == "elastic":
        be = ElasticBackend(use_kernel=True) if use_kernel else _ELASTIC
        return coded_matmul(A, B, scheme, backend=be, mask=mask, key=key)
    if backend == "shard_map":
        return coded_matmul(
            A, B, scheme, backend=ShardMapBackend(use_kernel=use_kernel),
            mask=mask, key=key,
        )
    return coded_matmul(A, B, scheme, backend="local", mask=mask, key=key)


def check_conformance(name, backend, seed, use_kernel=False):
    """One property check: random inputs + a random R-subset of responders
    must decode to exactly the oracle product on the given backend."""
    spec, scheme = build_scheme(name)
    rng = np.random.default_rng(seed)
    mult = int(rng.integers(1, 3))  # randomized spec: sizes x1 or x2
    A, B, expect = _random_problem(scheme, spec, rng, mult)
    # randomized responding subset of size exactly R
    live = rng.choice(scheme.N, size=scheme.R, replace=False)
    mask = np.zeros(scheme.N, dtype=bool)
    mask[live] = True
    key = jax.random.fold_in(KEY, seed)
    C = np.asarray(_run_backend(scheme, backend, A, B, mask, key, use_kernel))
    np.testing.assert_array_equal(
        C, expect,
        err_msg=f"{name} on {backend} (seed={seed}, live={sorted(live)}, "
                f"use_kernel={use_kernel})",
    )


needs8 = pytest.mark.skipif(NDEV < 8, reason=f"needs 8 devices, have {NDEV}")


def _backend_params():
    return [
        pytest.param(b, marks=needs8 if b == "shard_map" else ())
        for b in BACKENDS
    ]


def test_every_registered_family_is_covered():
    """The suite discovers a configuration for every family — including any
    registered after this test was written."""
    for name in registered_schemes():
        build_scheme(name)
    # both secure families must be present (the tentpole registration)
    assert {"ep_secure", "ep_rmfe_secure"} <= set(registered_schemes())


def _spawn_sweep(backend: str):
    """Start the full (family x seed) sweep for one backend in a fresh
    interpreter; returns the Popen handle."""
    import subprocess
    import sys

    paths = [os.path.dirname(os.path.abspath(__file__))]
    paths += [p for p in sys.path if p]
    code = (
        f"import sys; sys.path[:0] = {paths!r}\n"
        "import test_conformance as tc\n"
        "names = sorted(tc.registered_schemes())\n"
        "for name in names:\n"
        "    for seed in (0, 1):\n"
        f"        tc.check_conformance(name, {backend!r}, seed)\n"
        "print('SWEEP-OK', len(names))\n"
    )
    env = dict(os.environ)
    env.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )
    return subprocess.Popen(
        [sys.executable, "-c", code],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
    )


from repro import settings as repro_settings  # noqa: E402

if repro_settings.get_bool("conformance_inproc"):

    @pytest.mark.parametrize("backend", _backend_params())
    @pytest.mark.parametrize("name", sorted(registered_schemes()))
    @pytest.mark.parametrize("seed", [0, 1])
    def test_conformance_sweep(name, backend, seed):
        """Deterministic fallback sweep, fine-grained in-process variant
        (REPRO_CONFORMANCE_INPROC=1 — for running this file standalone
        with per-case reporting)."""
        check_conformance(name, backend, seed)

else:

    def test_conformance_sweep():
        """Deterministic fallback sweep: always runs, hypothesis or not.

        Quarantined into one subprocess per backend (all three running
        concurrently, so wall time stays near the single-backend cost):
        running this sweep in-process as part of the full suite
        deterministically crashes XLA's native ``backend_compile``
        (SIGSEGV, no Python traceback) once the parent process has
        accumulated ~125 compiled programs from the earlier test files —
        a CPU-client teardown bug in the pinned jaxlib, present since the
        repro.dist PR, and not reproducible when this file runs
        standalone.  Fresh interpreters keep the sweep's ~300
        compilations out of the parent process's compilation count while
        preserving the exact same coverage (set
        REPRO_CONFORMANCE_INPROC=1 for the fine-grained in-process
        variant).
        """
        backends = [b for b in BACKENDS if b != "shard_map" or NDEV >= 8]
        procs = {b: _spawn_sweep(b) for b in backends}
        failures = []
        for b, proc in procs.items():
            try:
                out, err = proc.communicate(timeout=1200)
            except Exception:
                proc.kill()
                out, err = proc.communicate()
                failures.append(f"{b}: timed out\n{err[-2000:]}")
                continue
            if proc.returncode != 0 or "SWEEP-OK" not in out:
                failures.append(
                    f"{b}: rc={proc.returncode}\n{out[-1000:]}\n"
                    f"{err[-4000:]}"
                )
        assert not failures, "\n---\n".join(failures)


@pytest.mark.parametrize(
    "backend",
    [pytest.param(b, marks=needs8 if b == "shard_map" else ())
     for b in ("shard_map", "elastic")],
)
@pytest.mark.parametrize("name", sorted(registered_schemes()))
def test_conformance_sweep_use_kernel(name, backend):
    """The distributed backends' forced-kernel path (workers compute their
    block product through the Pallas gr_matmul, interpret mode on CPU)
    must stay bit-identical for every family whose codeword ring is inside
    the kernel envelope — the configuration ShardMapBackend/ElasticBackend
    auto-select where the kernel compiles."""
    _, scheme = build_scheme(name)
    if not kernel_supported(scheme.ring):
        pytest.skip(f"{scheme.ring} outside the kernel envelope")
    check_conformance(name, backend, seed=3, use_kernel=True)


if HAVE_HYPOTHESIS:

    @pytest.mark.parametrize("backend", _backend_params())
    @pytest.mark.parametrize("name", sorted(registered_schemes()))
    @given(seed=st.integers(min_value=2, max_value=2**31 - 1))
    def test_conformance_property(name, backend, seed):
        """Property-based randomized specs/subsets (hypothesis installed)."""
        check_conformance(name, backend, seed)

else:  # pragma: no cover - exercised on minimal installs

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_conformance_property():
        pass


@pytest.fixture(scope="module")
def pool_backend():
    """One real multi-process worker pool shared by the pool sweep."""
    from repro.dist import LocalPool, PoolBackend

    with LocalPool(workers=4) as p:
        yield PoolBackend(p)


@pytest.mark.parametrize("name", sorted(registered_schemes()))
def test_conformance_pool_sweep(name, pool_backend):
    """The multi-process pool backend (repro.dist: real worker OS processes
    behind sockets) decodes bit-identically to LocalSimBackend for every
    registered family under a fixed encode key — the distributed runtime's
    headline conformance property.  The random R-subset mask doubles as the
    any-R check over real processes; mid-request SIGKILL coverage lives in
    tests/test_dist.py."""
    spec, scheme = build_scheme(name)
    rng = np.random.default_rng(11)
    A, B, expect = _random_problem(scheme, spec, rng, 1)
    live = rng.choice(scheme.N, size=scheme.R, replace=False)
    mask = jnp.asarray(np.isin(np.arange(scheme.N), live))
    key = jax.random.fold_in(KEY, 11)
    C_pool = coded_matmul(A, B, scheme, backend=pool_backend, mask=mask,
                          key=key)
    C_local = coded_matmul(A, B, scheme, backend="local", mask=mask, key=key)
    np.testing.assert_array_equal(
        np.asarray(C_pool), np.asarray(C_local),
        err_msg=f"{name}: pool != local (live={sorted(int(i) for i in live)})",
    )
    np.testing.assert_array_equal(
        np.asarray(C_pool), expect, err_msg=f"{name}: pool != oracle",
    )


def test_encode_at_matches_master_encode_for_every_family():
    """The at-worker encode (shard_map / elastic dispatch path) agrees with
    the master-side encode share by share, keyed or not."""
    for name in sorted(registered_schemes()):
        spec, scheme = build_scheme(name)
        rng = np.random.default_rng(99)
        A, B, _ = _random_problem(scheme, spec, rng, 1)
        FA = scheme.encode_a(A, key=KEY)
        GB = scheme.encode_b(B, key=KEY)
        assert FA.shape[0] == GB.shape[0] == scheme.N
        for i in (0, scheme.N - 1):
            np.testing.assert_array_equal(
                np.asarray(scheme.encode_a_at(A, i, key=KEY)),
                np.asarray(FA[i]), err_msg=f"{name} A-share {i}",
            )
            np.testing.assert_array_equal(
                np.asarray(scheme.encode_b_at(B, i, key=KEY)),
                np.asarray(GB[i]), err_msg=f"{name} B-share {i}",
            )
