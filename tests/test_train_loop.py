"""Integration: training loop learns, checkpoints, and resumes bit-identically."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.train import train


def test_loss_decreases_on_learnable_data(tmp_path):
    from repro.configs.base import ShapeConfig

    shape = ShapeConfig("t", 64, 16, "train")
    out = train(
        "gemma2-2b", smoke=True, steps=60, log_every=0, lr=1e-2,
        data_source="markov", shape=shape,
    )
    losses = out["losses"]
    assert losses[-1] < losses[0] - 0.3, losses[:3] + losses[-3:]


def test_checkpoint_resume_bit_identical(tmp_path):
    d = str(tmp_path / "ck")
    # run 8 steps, checkpointing at 4 and 8
    out_a = train(
        "starcoder2-3b", smoke=True, steps=8, ckpt_dir=d, ckpt_every=4,
        log_every=0,
    )
    # fresh process-state run: resume from step 8 checkpoint and do nothing more
    out_b = train(
        "starcoder2-3b", smoke=True, steps=12, ckpt_dir=d, ckpt_every=4,
        resume=True, log_every=0,
    )
    # deterministic replay: a run straight through 12 steps matches the
    # resumed run's losses on the overlapping steps
    out_c = train("starcoder2-3b", smoke=True, steps=12, log_every=0)
    np.testing.assert_allclose(
        np.asarray(out_b["losses"]), np.asarray(out_c["losses"][8:]), rtol=2e-2
    )


def test_compressed_grads_trains(tmp_path):
    from repro.configs.base import ShapeConfig

    shape = ShapeConfig("t", 64, 16, "train")
    out = train(
        "gemma2-2b", smoke=True, steps=60, log_every=0, lr=1e-2,
        compress_grads=True, data_source="markov", shape=shape,
    )
    losses = out["losses"]
    assert losses[-1] < losses[0] - 0.2
    assert np.isfinite(losses).all()
