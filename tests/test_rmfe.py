"""Tests for RMFE: the defining property and linearity, basic + concatenated."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.galois import make_ring
from repro.core.rmfe import BasicRMFE, ConcatRMFE, build_rmfe


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(1)


CASES = [
    # (ring args, n) for BasicRMFE
    ((2, 32, (3,)), 4),     # GR(2^32, 3): |T| = 8 >= 4
    ((2, 32, (3,)), 8),     # full exceptional set
    ((2, 16, (2,)), 2),     # paper experiment regime: n=2 small m
    ((3, 2, (2,)), 6),      # odd p
    ((2, 32, ()), 2),       # Z_{2^32}, n=2 (paper's setting over Z_2^e)
]


@pytest.mark.parametrize("ringargs,n", CASES)
def test_rmfe_property(ringargs, n, rng):
    base = make_ring(*ringargs)
    rmfe = BasicRMFE(base, n)
    assert rmfe.m >= 2 * n - 1
    x = base.random(rng, (5, n))
    y = base.random(rng, (5, n))
    gx, gy = rmfe.phi(x), rmfe.phi(y)
    assert gx.shape == (5, rmfe.ext.D)
    prod = rmfe.ext.mul(gx, gy)
    back = rmfe.psi(prod)
    expect = base.mul(x, y)
    assert np.array_equal(np.asarray(back), np.asarray(expect))


@pytest.mark.parametrize("ringargs,n", CASES[:2])
def test_rmfe_linearity(ringargs, n, rng):
    base = make_ring(*ringargs)
    rmfe = BasicRMFE(base, n)
    x = base.random(rng, (3, n))
    y = base.random(rng, (3, n))
    lhs = rmfe.phi(base.add(x, y))
    rhs = rmfe.ext.add(rmfe.phi(x), rmfe.phi(y))
    assert np.array_equal(np.asarray(lhs), np.asarray(rhs))
    g = rmfe.ext.random(rng, (3,))
    h = rmfe.ext.random(rng, (3,))
    lhs = rmfe.psi(rmfe.ext.add(g, h))
    rhs = base.add(rmfe.psi(g), rmfe.psi(h))
    assert np.array_equal(np.asarray(lhs), np.asarray(rhs))


def test_rmfe_sum_of_products(rng):
    """psi(sum_j phi(a_j) phi(b_j)) == sum_j a_j * b_j — the matmul identity."""
    base = make_ring(2, 32, (3,))
    rmfe = BasicRMFE(base, 4)
    r = 6
    a = base.random(rng, (r, 4))
    b = base.random(rng, (r, 4))
    acc = jnp.zeros((rmfe.ext.D,), dtype=base.dtype)
    expect = jnp.zeros((4, base.D), dtype=base.dtype)
    for j in range(r):
        acc = rmfe.ext.add(acc, rmfe.ext.mul(rmfe.phi(a[j]), rmfe.phi(b[j])))
        expect = base.add(expect, base.mul(a[j], b[j]))
    assert np.array_equal(np.asarray(rmfe.psi(acc)), np.asarray(expect))


def test_concat_rmfe_z2e(rng):
    """Over Z_{2^32} the base |T|=2; concatenation gives n=4, 6, 8..."""
    base = make_ring(2, 32, ())
    rmfe = ConcatRMFE(base, n2=2, n1=4)
    assert rmfe.n == 8
    x = base.random(rng, (3, 8))
    y = base.random(rng, (3, 8))
    prod = rmfe.ext.mul(rmfe.phi(x), rmfe.phi(y))
    back = rmfe.psi(prod)
    assert np.array_equal(np.asarray(back), np.asarray(base.mul(x, y)))


def test_build_rmfe_auto(rng):
    base = make_ring(2, 32, ())
    r = build_rmfe(base, 2)
    assert isinstance(r, BasicRMFE)
    r2 = build_rmfe(base, 6)
    assert isinstance(r2, ConcatRMFE) and r2.n >= 6
    base3 = make_ring(2, 32, (3,))
    r3 = build_rmfe(base3, 8)
    assert isinstance(r3, BasicRMFE)


def test_rmfe_rate():
    """m = Theta(n): check concrete rates match the construction (2n-1, +coprime bump)."""
    base = make_ring(2, 32, (3,))
    for n in [2, 3, 4, 8]:
        rmfe = BasicRMFE(base, n)
        assert rmfe.m <= 2 * n + 2  # 2n-1 plus at most a small coprimality bump
